//! The PJRT decode backend: one "GPU" running real AOT executables.
//!
//! The adaptive round loop itself lives in
//! [`crate::coordinator::core::InstanceCore`]; this module supplies the
//! hardware-facing half of the [`DecodeBackend`] contract:
//!
//! ```text
//! draft (SSM tree expansion, batched, level by level)   ← PJRT calls
//!   → predict node weights w = F(dl)                 (§5.2, shared core)
//!   → select draft budget n (layer-level search)     (§5.3, shared core)
//!   → verify top-n tree with the target model        (L1 kernel, here)
//!   → accept (greedy / stochastic spec sampling)     (§2.2, here)
//!   → commit accepted KV rows host-side              (here)
//! ```
//!
//! [`GenerationInstance`] is simply `InstanceCore<PjrtBackend>`, so every
//! scheduling-policy change is automatically exercised by the calibrated
//! simulation plane as well ([`crate::sim::engine::SimBackend`]).

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
use crate::coordinator::backend::{DecodeBackend, SpecRound};
use crate::coordinator::core::InstanceCore;
use crate::coordinator::metrics::{InstanceMetrics, SampleLatency, Stopwatch};
use crate::coordinator::migration::{
    pack_hierarchical, unpack_hierarchical, HierarchicalKv, SampleControl,
};
use crate::runtime::{Engine, HostTensor, Manifest, ModelStore};
use crate::spec::kvcache::{BatchedCache, KvCache};
use crate::spec::sampler;
use crate::spec::tree::{CandidateTree, Selection};
use crate::spec::verify::{accept_greedy, accept_stochastic, AcceptOutcome};
use crate::utils::rng::Rng;

pub use crate::coordinator::core::DecodeMode;

/// A sample entering the instance.
#[derive(Clone, Debug)]
pub struct SampleTask {
    /// Caller-assigned sample id (unique within a batch).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// End-of-sequence token id.
    pub eos: i32,
    /// Wall-clock instant the task was submitted to the service (set by
    /// the streaming [`GenerationService::submit`] path; None for plain
    /// batch tasks, which then carry no latency record).
    ///
    /// [`GenerationService::submit`]: crate::coordinator::driver::GenerationService::submit
    pub submitted_at: Option<Instant>,
}

/// A completed sample leaving the instance.
#[derive(Clone, Debug)]
pub struct FinishedSample {
    /// Caller-assigned sample id.
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generated response (truncated at EOS / the generation budget).
    pub response: Vec<i32>,
    /// Decode rounds this sample participated in.
    pub rounds: usize,
    /// Draft tokens the target accepted for this sample.
    pub drafts_accepted: usize,
    /// Draft tokens proposed for this sample.
    pub drafts_proposed: usize,
    /// Serving latencies (queueing delay, TTFT, TPOT) measured from
    /// submission; None for tasks without a submission timestamp.
    pub latency: Option<SampleLatency>,
}

/// Live decoding state of one sample.
pub struct LiveSample {
    /// The originating task (prompt, budget, submission stamp).
    pub task: SampleTask,
    /// Response tokens so far; the last one is the *pending* token whose
    /// KV is not yet committed.
    pub generated: Vec<i32>,
    /// Committed cache length (= prompt_len + generated.len() - 1).
    pub prefix_len: usize,
    /// Target-model KV rows of this sample.
    pub target_cache: KvCache,
    /// Draft-model KV rows of this sample.
    pub draft_cache: KvCache,
    /// Decode rounds this sample participated in.
    pub rounds: usize,
    /// Draft tokens the target accepted for this sample.
    pub drafts_accepted: usize,
    /// Draft tokens proposed for this sample.
    pub drafts_proposed: usize,
    /// Wall-clock instant the sample entered a decode slot (prefill).
    pub admitted_at: Option<Instant>,
    /// Wall-clock instant of the first generated token (prefill end —
    /// prefill samples the first pending token from the target).
    pub first_token_at: Option<Instant>,
}

impl LiveSample {
    /// The pending (uncommitted) token that seeds the next round.
    pub fn pending(&self) -> i32 {
        *self.generated.last().expect("live sample has a pending token")
    }

    /// Prompt + generated tokens (the §6.1 migration-score length).
    pub fn seq_len(&self) -> usize {
        self.task.prompt.len() + self.generated.len()
    }

    /// Mean accepted drafts per round (migration-choice feature, §6.1).
    pub fn mean_accepted(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.drafts_accepted as f64 / self.rounds as f64
        }
    }

    fn is_done(&self) -> bool {
        self.generated.contains(&self.task.eos)
            || self.generated.len() >= self.task.max_new_tokens
    }

    fn into_finished(self) -> FinishedSample {
        // Serving latencies, when the task carried a submission stamp
        // (streaming path). Finish time is "now": retirement happens at
        // the step boundary that produced the final token.
        let latency = match (self.task.submitted_at, self.admitted_at, self.first_token_at) {
            (Some(sub), Some(adm), Some(first)) => {
                let finish = Instant::now();
                let n_out = self.generated.len();
                let tpot = if n_out > 1 {
                    finish.duration_since(first).as_secs_f64() / (n_out - 1) as f64
                } else {
                    0.0
                };
                Some(SampleLatency {
                    queue_secs: adm.duration_since(sub).as_secs_f64(),
                    ttft_secs: first.duration_since(sub).as_secs_f64(),
                    tpot_secs: tpot,
                })
            }
            _ => None,
        };
        let mut response = self.generated;
        if let Some(p) = response.iter().position(|&t| t == self.task.eos) {
            response.truncate(p + 1);
        }
        response.truncate(self.task.max_new_tokens);
        FinishedSample {
            id: self.task.id,
            prompt: self.task.prompt,
            response,
            rounds: self.rounds,
            drafts_accepted: self.drafts_accepted,
            drafts_proposed: self.drafts_proposed,
            latency,
        }
    }
}

/// Backend-private context threaded from the draft phase to verification.
pub struct PjrtDraftCtx {
    /// Compiled batch bucket used this round.
    b: usize,
    /// Final draft (k_new, v_new) rows, level order == candidate order.
    draft_rows: (HostTensor, HostTensor),
    /// Per-sample full draft distributions by candidate index.
    dists: Vec<HashMap<usize, Vec<f32>>>,
    /// Round stopwatch (started at draft begin) + draft-phase seconds,
    /// for the `t_sd` observation.
    step_sw: Stopwatch,
    draft_secs: f64,
}

/// The PJRT execution backend: engine + weights + batched KV state.
pub struct PjrtBackend {
    /// Compiled-artifact execution engine (one PJRT client).
    pub engine: Engine,
    /// Target-model weights.
    pub target: ModelStore,
    /// Draft-model weights.
    pub draft: ModelStore,
    /// Run configuration (spec/selector knobs).
    pub cfg: RunConfig,
    rng: Rng,
    batch_target: Option<BatchedCache>,
    batch_draft: Option<BatchedCache>,
    batch_dirty: bool,
    /// Stage-1 buffers keyed by migration order:
    /// (draft, target) caches + sample ids.
    mig_in: BTreeMap<u64, (Vec<(KvCache, KvCache)>, Vec<u64>)>,
    started: Instant,
}

/// A generation instance on real PJRT executables: the shared adaptive
/// decode loop over the [`PjrtBackend`].
pub type GenerationInstance = InstanceCore<PjrtBackend>;

impl InstanceCore<PjrtBackend> {
    /// Build one PJRT-backed instance from loaded stores + manifest.
    pub fn new(
        id: usize,
        manifest: Rc<Manifest>,
        target: ModelStore,
        draft: ModelStore,
        cfg: RunConfig,
        mode: DecodeMode,
        seed: u64,
    ) -> Result<Self> {
        let engine = Engine::new(manifest)?;
        let selector = cfg.selector.clone();
        let backend = PjrtBackend {
            engine,
            target,
            draft,
            cfg,
            rng: Rng::new(seed),
            batch_target: None,
            batch_draft: None,
            batch_dirty: true,
            mig_in: BTreeMap::new(),
            started: Instant::now(),
        };
        Ok(InstanceCore::with_backend(id, backend, mode, selector))
    }
}

impl PjrtBackend {
    /// Run one causal chunk through `{model}_tree_b1_tT`, commit all rows,
    /// return the logits of the LAST chunk position.
    fn prefill_chunk(
        &mut self,
        model: &str,
        cache: &mut KvCache,
        toks: &[i32],
        offset: usize,
    ) -> Result<Vec<f32>> {
        let man = self.engine.manifest.clone();
        let t_bucket = man.tree_bucket(toks.len()).unwrap();
        let name = man.tree_artifact(model, 1, toks.len())?;
        let dims = man.model(model);
        let t = toks.len();

        let mut tokens = vec![0i32; t_bucket];
        tokens[..t].copy_from_slice(toks);
        let mut positions = vec![0i32; t_bucket];
        for i in 0..t {
            positions[i] = (offset + i) as i32;
        }
        let mut mask = vec![0f32; t_bucket * t_bucket];
        for i in 0..t_bucket {
            if i < t {
                // causal within the chunk (cache prefix handled by plen)
                for j in 0..=i {
                    mask[i * t_bucket + j] = 1.0;
                }
            } else {
                mask[i * t_bucket + i] = 1.0; // padded row: self only
            }
        }
        let (kc, vc) = cache_tensors_single(cache);
        let tokens_t = HostTensor::i32(vec![1, t_bucket], tokens);
        let pos_t = HostTensor::i32(vec![1, t_bucket], positions);
        let plen_t = HostTensor::i32(vec![1], vec![offset as i32]);
        let mask_t = HostTensor::f32(vec![1, t_bucket, t_bucket], mask);
        let store = if model == "target" { &self.target } else { &self.draft };
        let stores: BTreeMap<String, &ModelStore> =
            [(model.to_string(), store)].into_iter().collect();
        let data: BTreeMap<&str, &HostTensor> = [
            ("kc", &kc),
            ("vc", &vc),
            ("tokens", &tokens_t),
            ("positions", &pos_t),
            ("prefix_len", &plen_t),
            ("tree_mask", &mask_t),
        ]
        .into_iter()
        .collect();
        let outs = self.engine.run_artifact(&name, &stores, &data)?;
        // Commit every real row.
        for i in 0..t {
            cache.commit_row(&outs[1], &outs[2], 0, i, offset + i);
        }
        // Last real position's logits.
        let v = dims.vocab;
        let logits = outs[0].as_f32();
        Ok(logits[(t - 1) * v..t * v].to_vec())
    }

    /// Rebuild the batched KV tensors when batch composition changed.
    fn rebuild_batches_if_needed(&mut self, live: &[LiveSample], b: usize) -> Result<()> {
        let man = self.engine.manifest.clone();
        let need_rebuild = self.batch_dirty
            || self.batch_target.as_ref().map(|bt| bt.batch) != Some(b);
        if !need_rebuild {
            return Ok(());
        }
        let td = &man.target;
        let dd = &man.draft;
        let mut bt = BatchedCache::new(td.n_layers, td.n_heads, td.max_seq, td.d_head, b);
        let mut bd = BatchedCache::new(dd.n_layers, dd.n_heads, dd.max_seq, dd.d_head, b);
        for (i, s) in live.iter().enumerate() {
            bt.load_slot(i, s.task.id, &s.target_cache);
            bd.load_slot(i, s.task.id, &s.draft_cache);
        }
        self.batch_target = Some(bt);
        self.batch_draft = Some(bd);
        self.batch_dirty = false;
        Ok(())
    }
}

impl DecodeBackend for PjrtBackend {
    type Task = SampleTask;
    type Sample = LiveSample;
    type Finished = FinishedSample;
    type DraftCtx = PjrtDraftCtx;
    type KvPayload = HierarchicalKv;
    type Control = SampleControl;

    fn sample_id(s: &LiveSample) -> u64 {
        s.task.id
    }

    fn committed_len(s: &LiveSample) -> usize {
        s.prefix_len
    }

    fn seq_len(s: &LiveSample) -> usize {
        s.seq_len()
    }

    fn mean_accepted(s: &LiveSample) -> f64 {
        s.mean_accepted()
    }

    fn is_done(s: &LiveSample) -> bool {
        s.is_done()
    }

    fn finish(s: LiveSample) -> FinishedSample {
        s.into_finished()
    }

    fn control_of(s: &LiveSample) -> SampleControl {
        SampleControl::from_live(s)
    }

    /// Decoding-slot capacity (largest compiled batch bucket).
    fn capacity(&self) -> usize {
        *self.engine.manifest.batch_buckets.iter().max().unwrap_or(&1)
    }

    fn max_draft(&self) -> usize {
        self.cfg
            .spec
            .max_draft
            .min(*self.engine.manifest.tree_buckets.iter().max().unwrap_or(&1))
    }

    fn max_seq(&self) -> usize {
        self.engine.manifest.target.max_seq
    }

    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn on_batch_change(&mut self) {
        self.batch_dirty = true;
    }

    /// Prefill a prompt through both models, chunked by tree buckets.
    fn prefill(&mut self, task: SampleTask, metrics: &mut InstanceMetrics) -> Result<LiveSample> {
        let admitted = Instant::now();
        let mut sw = Stopwatch::start();
        let man = self.engine.manifest.clone();
        let td = &man.target;
        let dd = &man.draft;
        let mut target_cache = KvCache::new(td.n_layers, td.n_heads, td.max_seq, td.d_head);
        let mut draft_cache = KvCache::new(dd.n_layers, dd.n_heads, dd.max_seq, dd.d_head);
        if task.prompt.is_empty() {
            bail!("empty prompt for sample {}", task.id);
        }
        let max_chunk = *man.tree_buckets.iter().max().unwrap();
        let mut first_probs: Vec<f32> = Vec::new();
        let mut done = 0usize;
        while done < task.prompt.len() {
            let chunk = (task.prompt.len() - done).min(max_chunk);
            let toks = &task.prompt[done..done + chunk];
            // causal-chain "tree": node i's parent is i-1.
            let logits = self.prefill_chunk("target", &mut target_cache, toks, done)?;
            self.prefill_chunk("draft", &mut draft_cache, toks, done)?;
            if done + chunk == task.prompt.len() {
                first_probs = logits;
            }
            done += chunk;
        }
        // First pending token from the target distribution at the prompt end.
        let pending = if self.cfg.spec.greedy {
            sampler::argmax(&first_probs) as i32
        } else {
            let p = sampler::softmax(&first_probs, self.cfg.spec.temperature);
            sampler::sample(&p, &mut self.rng) as i32
        };
        metrics.prefill_secs += sw.lap();
        // The first generated token exists at prefill end; admission was
        // at prefill start. Both stamps anchor the queue-delay/TTFT
        // metrics of the streaming path.
        Ok(LiveSample {
            prefix_len: task.prompt.len(),
            task,
            generated: vec![pending],
            target_cache,
            draft_cache,
            rounds: 0,
            drafts_accepted: 0,
            drafts_proposed: 0,
            admitted_at: Some(admitted),
            first_token_at: Some(Instant::now()),
        })
    }

    // ------------------------------------------------------------------
    // Autoregressive baseline step
    // ------------------------------------------------------------------

    fn step_ar(&mut self, live: &mut [LiveSample], metrics: &mut InstanceMetrics) -> Result<()> {
        let man = self.engine.manifest.clone();
        let b_live = live.len();
        let b = man.batch_bucket(b_live).unwrap();
        self.rebuild_batches_if_needed(live, b)?;
        let mut sw = Stopwatch::start();

        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut plen = vec![0i32; b];
        let mut mask = vec![0f32; b];
        for (i, s) in live.iter().enumerate() {
            tokens[i] = s.pending();
            positions[i] = s.prefix_len as i32;
            plen[i] = s.prefix_len as i32;
        }
        for m in mask.iter_mut() {
            *m = 1.0; // T=1 self mask
        }
        let name = man.tree_artifact("target", b, 1)?;
        // Borrow the batched KV tensors (no copy: they are only read
        // while marshalling the call).
        let (kc, vc) = {
            let (k, v) = self.batch_target.as_ref().unwrap().tensors();
            (k, v)
        };
        let tokens_t = HostTensor::i32(vec![b, 1], tokens);
        let pos_t = HostTensor::i32(vec![b, 1], positions);
        let plen_t = HostTensor::i32(vec![b], plen);
        let mask_t = HostTensor::f32(vec![b, 1, 1], mask);
        let stores: BTreeMap<String, &ModelStore> =
            [("target".to_string(), &self.target)].into_iter().collect();
        let data: BTreeMap<&str, &HostTensor> = [
            ("kc", kc),
            ("vc", vc),
            ("tokens", &tokens_t),
            ("positions", &pos_t),
            ("prefix_len", &plen_t),
            ("tree_mask", &mask_t),
        ]
        .into_iter()
        .collect();
        let outs = self.engine.run_artifact(&name, &stores, &data)?;
        metrics.verify_secs += sw.lap();

        let v = man.target.vocab;
        let greedy = self.cfg.spec.greedy;
        let temp = self.cfg.spec.temperature;
        for (i, s) in live.iter_mut().enumerate() {
            let logits = &outs[0].as_f32()[i * v..(i + 1) * v];
            let next = if greedy {
                sampler::argmax(logits) as i32
            } else {
                let p = sampler::softmax(logits, temp);
                sampler::sample(&p, &mut self.rng) as i32
            };
            let dest = s.prefix_len;
            s.target_cache.commit_row(&outs[1], &outs[2], i, 0, dest);
            self.batch_target
                .as_mut()
                .unwrap()
                .commit_row(&outs[1], &outs[2], i, i, 0, dest);
            s.generated.push(next);
            s.prefix_len += 1;
            s.rounds += 1;
            metrics.tokens_out += 1;
        }
        metrics.commit_secs += sw.lap();
        metrics.rounds += 1;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Speculative round: draft phase
    // ------------------------------------------------------------------

    /// Expand candidate trees for every live sample with batched draft
    /// calls, level by level (EAGLE-2-style: widest-`dl` nodes first).
    fn draft(
        &mut self,
        live: &mut [LiveSample],
        metrics: &mut InstanceMetrics,
    ) -> Result<(Vec<CandidateTree>, PjrtDraftCtx)> {
        let man = self.engine.manifest.clone();
        let b_live = live.len();
        let b = man.batch_bucket(b_live).unwrap();
        self.rebuild_batches_if_needed(live, b)?;
        let step_sw = Stopwatch::start();
        let mut sw = Stopwatch::start();

        let dd = man.draft.clone();
        let n_live = live.len();
        let branch = self.cfg.spec.branch;
        let max_depth = self.cfg.spec.max_depth;
        let max_tree = self
            .cfg
            .spec
            .max_draft
            .min(*man.tree_buckets.iter().max().unwrap());
        // Cap expansions per level so trees stay within buckets.
        let expand_width = 4usize;

        let mut trees: Vec<CandidateTree> = live
            .iter()
            .map(|s| CandidateTree::new(s.pending()))
            .collect();
        let mut dists: Vec<HashMap<usize, Vec<f32>>> = vec![Default::default(); n_live];
        let mut last_rows: Option<(HostTensor, HostTensor)> = None;

        for depth in 0..=max_depth {
            // Feed the whole tree-so-far (level order == insertion order).
            let t_need = trees.iter().map(|t| t.len()).max().unwrap_or(1);
            let t_bucket = match man.tree_bucket(t_need) {
                Some(t) => t,
                None => break,
            };
            let name = man.tree_artifact("draft", b, t_need)?;

            let mut tokens = vec![0i32; b * t_bucket];
            let mut positions = vec![0i32; b * t_bucket];
            let mut plen = vec![0i32; b];
            let mut mask = vec![0f32; b * t_bucket * t_bucket];
            for i in 0..b {
                if i < n_live {
                    let s = &live[i];
                    let tr = &trees[i];
                    for (j, node) in tr.nodes.iter().enumerate() {
                        tokens[i * t_bucket + j] = node.token;
                        positions[i * t_bucket + j] = (s.prefix_len + node.depth) as i32;
                        for &a in &tr.path(j) {
                            mask[(i * t_bucket + j) * t_bucket + a] = 1.0;
                        }
                    }
                    for j in tr.len()..t_bucket {
                        mask[(i * t_bucket + j) * t_bucket + j] = 1.0;
                        positions[i * t_bucket + j] = s.prefix_len as i32;
                    }
                    plen[i] = s.prefix_len as i32;
                } else {
                    for j in 0..t_bucket {
                        mask[(i * t_bucket + j) * t_bucket + j] = 1.0;
                    }
                }
            }
            let (kc, vc) = {
                let (k, v) = self.batch_draft.as_ref().unwrap().tensors();
                (k, v)
            };
            let tokens_t = HostTensor::i32(vec![b, t_bucket], tokens);
            let pos_t = HostTensor::i32(vec![b, t_bucket], positions);
            let plen_t = HostTensor::i32(vec![b], plen);
            let mask_t = HostTensor::f32(vec![b, t_bucket, t_bucket], mask);
            let stores: BTreeMap<String, &ModelStore> =
                [("draft".to_string(), &self.draft)].into_iter().collect();
            let data: BTreeMap<&str, &HostTensor> = [
                ("kc", kc),
                ("vc", vc),
                ("tokens", &tokens_t),
                ("positions", &pos_t),
                ("prefix_len", &plen_t),
                ("tree_mask", &mask_t),
            ]
            .into_iter()
            .collect();
            let outs = self.engine.run_artifact(&name, &stores, &data)?;
            last_rows = Some((outs[1].clone(), outs[2].clone()));

            if depth == max_depth {
                break;
            }
            // Expand: per sample, top `expand_width` nodes of this level
            // by dl, each adding `branch` children.
            let v = dd.vocab;
            for i in 0..n_live {
                let level_nodes = trees[i].level(depth);
                if trees[i].len() >= max_tree || level_nodes.is_empty() {
                    continue;
                }
                let mut ranked = level_nodes.clone();
                // Descending dl: expand the most promising nodes (EAGLE-2).
                ranked.sort_by(|&a, &bn| {
                    trees[i].nodes[bn]
                        .dl
                        .partial_cmp(&trees[i].nodes[a].dl)
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                for &node_idx in ranked.iter().take(expand_width) {
                    if trees[i].len() >= max_tree {
                        break;
                    }
                    let off = (i * t_bucket + node_idx) * v;
                    let logits = &outs[0].as_f32()[off..off + v];
                    let probs = sampler::softmax(logits, self.cfg.spec.temperature);
                    dists[i].insert(node_idx, probs.clone());
                    for &tok in sampler::top_k(&probs, branch).iter() {
                        if trees[i].len() >= max_tree {
                            break;
                        }
                        trees[i].add_child(node_idx, tok as i32, probs[tok]);
                    }
                }
            }
        }

        metrics.draft_secs += sw.lap();
        let draft_secs = step_sw.elapsed();
        Ok((
            trees,
            PjrtDraftCtx {
                b,
                draft_rows: last_rows.expect("at least one draft level ran"),
                dists,
                step_sw,
                draft_secs,
            },
        ))
    }

    // ------------------------------------------------------------------
    // Speculative round: verify + accept + commit
    // ------------------------------------------------------------------

    fn verify_accept(
        &mut self,
        live: &mut [LiveSample],
        trees: &[CandidateTree],
        ctx: PjrtDraftCtx,
        selections: &[Selection],
        metrics: &mut InstanceMetrics,
    ) -> Result<SpecRound> {
        let man = self.engine.manifest.clone();
        let b = ctx.b;
        let mut sw = Stopwatch::start();

        let t_need = selections.iter().map(|s| s.len()).max().unwrap_or(1);
        let t_bucket = man.tree_bucket(t_need).unwrap();
        let name = man.tree_artifact("target", b, t_need)?;

        let mut tokens = vec![0i32; b * t_bucket];
        let mut positions = vec![0i32; b * t_bucket];
        let mut plen = vec![0i32; b];
        let mut mask = vec![0f32; b * t_bucket * t_bucket];
        for i in 0..b {
            if i < live.len() {
                let s = &live[i];
                let sel = &selections[i];
                let (tk, mk) = sel.padded(t_bucket);
                tokens[i * t_bucket..(i + 1) * t_bucket].copy_from_slice(&tk);
                mask[i * t_bucket * t_bucket..(i + 1) * t_bucket * t_bucket]
                    .copy_from_slice(&mk);
                let pos = sel.positions(s.prefix_len);
                for (j, &p) in pos.iter().enumerate() {
                    positions[i * t_bucket + j] = p;
                }
                for j in sel.len()..t_bucket {
                    positions[i * t_bucket + j] = s.prefix_len as i32;
                }
                plen[i] = s.prefix_len as i32;
            } else {
                for j in 0..t_bucket {
                    mask[(i * t_bucket + j) * t_bucket + j] = 1.0;
                }
            }
        }
        // Borrow the batched KV tensors (no copy: they are only read
        // while marshalling the call).
        let (kc, vc) = {
            let (k, v) = self.batch_target.as_ref().unwrap().tensors();
            (k, v)
        };
        let tokens_t = HostTensor::i32(vec![b, t_bucket], tokens);
        let pos_t = HostTensor::i32(vec![b, t_bucket], positions);
        let plen_t = HostTensor::i32(vec![b], plen);
        let mask_t = HostTensor::f32(vec![b, t_bucket, t_bucket], mask);
        let stores: BTreeMap<String, &ModelStore> =
            [("target".to_string(), &self.target)].into_iter().collect();
        let data: BTreeMap<&str, &HostTensor> = [
            ("kc", kc),
            ("vc", vc),
            ("tokens", &tokens_t),
            ("positions", &pos_t),
            ("prefix_len", &plen_t),
            ("tree_mask", &mask_t),
        ]
        .into_iter()
        .collect();
        let outs = self.engine.run_artifact(&name, &stores, &data)?;
        metrics.verify_secs += sw.lap();

        // Observed t_sd for the predictor (draft + verify wall time).
        let n_draft_total: usize = selections.iter().map(|s| s.len()).sum();
        let tsd_secs = ctx.step_sw.elapsed().max(ctx.draft_secs);

        // ---- acceptance + commit -----------------------------------
        let v = man.target.vocab;
        let greedy = self.cfg.spec.greedy;
        let temp = self.cfg.spec.temperature;
        let mut observations: Vec<(f32, bool)> = Vec::new();
        for (i, s) in live.iter_mut().enumerate() {
            let sel = &selections[i];
            let logit_rows: Vec<&[f32]> = (0..sel.len())
                .map(|j| {
                    let off = (i * t_bucket + j) * v;
                    &outs[0].as_f32()[off..off + v]
                })
                .collect();
            let outcome: AcceptOutcome = if greedy {
                accept_greedy(sel, &logit_rows)
            } else {
                let probs: Vec<Vec<f32>> =
                    logit_rows.iter().map(|r| sampler::softmax(r, temp)).collect();
                let draft_q: Vec<f32> =
                    sel.order.iter().map(|&ci| trees[i].nodes[ci].o).collect();
                let dists: Vec<Vec<f32>> = sel
                    .order
                    .iter()
                    .map(|&ci| ctx.dists[i].get(&ci).cloned().unwrap_or_default())
                    .collect();
                accept_stochastic(sel, &probs, &draft_q, &dists, &mut self.rng)
            };
            metrics.accept_secs += sw.lap();

            // Predictor observations: every non-root selected node.
            let on_path: std::collections::HashSet<usize> =
                outcome.path.iter().copied().collect();
            for (j, &ci) in sel.order.iter().enumerate() {
                if j == 0 {
                    continue;
                }
                observations.push((trees[i].nodes[ci].dl, on_path.contains(&j)));
            }

            // Commit target KV rows for the accepted path.
            let base = s.prefix_len;
            for (step_k, &selpos) in outcome.path.iter().enumerate() {
                let dest = base + step_k;
                s.target_cache.commit_row(&outs[1], &outs[2], i, selpos, dest);
                self.batch_target.as_mut().unwrap().commit_row(
                    &outs[1],
                    &outs[2],
                    i,
                    i,
                    selpos,
                    dest,
                );
                // Commit draft KV for the same token (draft rows are in
                // level order of the candidate tree, which equals the
                // candidate-insertion order).
                let cand_idx = sel.order[selpos];
                s.draft_cache.commit_row(
                    &ctx.draft_rows.0,
                    &ctx.draft_rows.1,
                    i,
                    cand_idx,
                    dest,
                );
                self.batch_draft.as_mut().unwrap().commit_row(
                    &ctx.draft_rows.0,
                    &ctx.draft_rows.1,
                    i,
                    i,
                    cand_idx,
                    dest,
                );
            }

            let k = outcome.accepted_drafts;
            s.prefix_len += k + 1;
            s.generated.extend_from_slice(&outcome.new_tokens);
            s.rounds += 1;
            s.drafts_accepted += k;
            s.drafts_proposed += sel.len() - 1;
            metrics.tokens_out += outcome.new_tokens.len() as u64;
            metrics.drafts_accepted += k as u64;
            metrics.drafts_proposed += (sel.len() - 1) as u64;
            metrics.commit_secs += sw.lap();
        }
        metrics.rounds += 1;
        Ok(SpecRound { observations, n_draft_total, tsd_secs })
    }

    // ------------------------------------------------------------------
    // Two-stage KV migration (§6.2)
    // ------------------------------------------------------------------

    fn kv_bytes(&self, s: &LiveSample, from: usize, to: usize) -> usize {
        2 * to.saturating_sub(from)
            * (s.target_cache.row_elems() + s.draft_cache.row_elems())
            * 4
    }

    fn kv_extract(&self, items: &[(&LiveSample, (usize, usize))]) -> HierarchicalKv {
        let mut drafts = Vec::with_capacity(items.len());
        let mut targets = Vec::with_capacity(items.len());
        let mut ids = Vec::with_capacity(items.len());
        let mut ranges = Vec::with_capacity(items.len());
        for (s, range) in items {
            drafts.push(&s.draft_cache);
            targets.push(&s.target_cache);
            ids.push(s.task.id);
            ranges.push(*range);
        }
        pack_hierarchical(&drafts, &targets, &ids, &ranges)
    }

    /// Phase 3: unpack the Stage-1 bulk into fresh per-sample caches
    /// immediately, keyed by migration order.
    fn stage1_store(&mut self, order: u64, _from: usize, kv: HierarchicalKv) -> Result<()> {
        let man = self.engine.manifest.clone();
        let n = kv.spans.len();
        let mut caches: Vec<(KvCache, KvCache)> = (0..n)
            .map(|_| {
                (
                    KvCache::new(
                        man.draft.n_layers,
                        man.draft.n_heads,
                        man.draft.max_seq,
                        man.draft.d_head,
                    ),
                    KvCache::new(
                        man.target.n_layers,
                        man.target.n_heads,
                        man.target.max_seq,
                        man.target.d_head,
                    ),
                )
            })
            .collect();
        {
            let mut drafts: Vec<&mut KvCache> = Vec::new();
            let mut targets: Vec<&mut KvCache> = Vec::new();
            for (d, t) in caches.iter_mut() {
                drafts.push(d);
                targets.push(t);
            }
            unpack_hierarchical(&kv, &mut drafts, &mut targets);
        }
        let ids = kv.spans.iter().map(|s| s.id).collect();
        self.mig_in.insert(order, (caches, ids));
        Ok(())
    }

    /// Drop a stashed Stage-1 bulk whose order was cancelled (peer crash
    /// reconciliation) — frees the unpacked per-sample caches.
    fn stage1_discard(&mut self, order: u64) {
        self.mig_in.remove(&order);
    }

    /// Merge the Stage-2 delta into the stashed caches and rebuild live
    /// samples from their control snapshots.
    fn stage2_restore(
        &mut self,
        order: u64,
        _from: usize,
        delta: HierarchicalKv,
        control: Vec<SampleControl>,
    ) -> Result<Vec<LiveSample>> {
        let (mut caches, ids) = self.mig_in.remove(&order).unwrap_or_default();
        if !delta.spans.is_empty() {
            // Delta spans arrive in Stage-1 order (an order-preserving
            // subset: victims that finished during the overlap step were
            // dropped), so disjoint &mut borrows can be split off in
            // sequence.
            let mut drafts: Vec<&mut KvCache> = Vec::new();
            let mut targets: Vec<&mut KvCache> = Vec::new();
            let mut rest: &mut [(KvCache, KvCache)] = &mut caches[..];
            let mut rest_ids: &[u64] = &ids[..];
            for span in &delta.spans {
                let pos = rest_ids
                    .iter()
                    .position(|id| *id == span.id)
                    .ok_or_else(|| anyhow!("stage2 delta for unknown sample {}", span.id))?;
                let tail = std::mem::take(&mut rest);
                let (_, at) = tail.split_at_mut(pos);
                let (item, after) = at.split_first_mut().expect("pos in range");
                drafts.push(&mut item.0);
                targets.push(&mut item.1);
                rest = after;
                rest_ids = &rest_ids[pos + 1..];
            }
            unpack_hierarchical(&delta, &mut drafts, &mut targets);
        }
        let mut out = Vec::with_capacity(control.len());
        for ctl in control {
            let pos = ids
                .iter()
                .position(|id| *id == ctl.task.id)
                .ok_or_else(|| anyhow!("stage2 control for unknown sample {}", ctl.task.id))?;
            let (draft_cache, target_cache) = {
                let c = &caches[pos];
                (c.0.clone(), c.1.clone())
            };
            out.push(LiveSample {
                task: ctl.task,
                generated: ctl.generated,
                prefix_len: ctl.prefix_len,
                target_cache,
                draft_cache,
                rounds: ctl.rounds,
                drafts_accepted: ctl.drafts_accepted,
                drafts_proposed: ctl.drafts_proposed,
                admitted_at: ctl.admitted_at,
                first_token_at: ctl.first_token_at,
            });
        }
        Ok(out)
    }
}

/// Single-sample cache tensors in batch-1 layout (prefill helper).
fn cache_tensors_single(cache: &KvCache) -> (HostTensor, HostTensor) {
    let (l, h, s, d) = (cache.layers, cache.heads, cache.max_seq, cache.d_head);
    let mut bt = BatchedCache::new(l, h, s, d, 1);
    bt.load_slot(0, 0, cache);
    let (k, v) = bt.tensors();
    (k.clone(), v.clone())
}
