//! Per-instance stage timing, counters and serving-latency summaries.
//!
//! Feeds Fig 3 (stage breakdown), Fig 5/14 (throughput-over-time curves),
//! the §7.7 overhead analysis (WDS = `select_secs`, SRD lives in the
//! driver, SM = `migration_secs`) and — for streaming workloads — the
//! per-sample TTFT/TPOT/queueing-delay percentiles
//! ([`SampleLatency`]/[`LatencySummary`]) reported by both decode planes.

use std::time::Instant;

use crate::utils::stats;

/// Per-stage wall-time and counter ledger of one generation instance.
#[derive(Clone, Debug, Default)]
pub struct InstanceMetrics {
    // ---- stage wall-times (seconds) ----
    /// Seconds spent prefilling admitted prompts.
    pub prefill_secs: f64,
    /// Seconds spent expanding candidate trees (draft model).
    pub draft_secs: f64,
    /// Seconds spent in drafting-strategy selection (§7.7 WDS).
    pub select_secs: f64,
    /// Seconds spent verifying selected subtrees (target model).
    pub verify_secs: f64,
    /// Seconds spent in the acceptance walk.
    pub accept_secs: f64,
    /// Seconds spent committing accepted KV rows.
    pub commit_secs: f64,
    /// Seconds spent packing/unpacking migration payloads (§7.7 SM).
    pub migration_secs: f64,
    // ---- counters ----
    /// Decode rounds executed.
    pub rounds: u64,
    /// Tokens generated (committed) on this instance.
    pub tokens_out: u64,
    /// Draft tokens proposed to verification.
    pub drafts_proposed: u64,
    /// Draft tokens the target accepted.
    pub drafts_accepted: u64,
    /// Samples retired on this instance.
    pub samples_finished: u64,
    /// Samples that arrived via the §6.2 migration protocol.
    pub samples_migrated_in: u64,
    /// Samples that left via the §6.2 migration protocol.
    pub samples_migrated_out: u64,
    /// Outbound migration orders this instance aborted after a handshake
    /// timeout on an unreliable transport (victims returned to the local
    /// batch; see `InstanceCore::abort_handshake`).
    pub orders_aborted: u64,
    /// Whole-instance crashes this instance suffered (its resident
    /// samples were salvaged and requeued onto survivors; see
    /// `InstanceCore::crash_drain`).
    pub crashes: u64,
    /// Times this instance was parked by the RLHF loop plane so its slot
    /// could run a colocated training step (samples salvaged/requeued via
    /// the same `crash_drain` machinery as a crash, but no recovery draw —
    /// the instance revives deterministically at the weight barrier).
    pub preemptions: u64,
    /// Σ seconds between a crash and the instant each crash-requeued
    /// sample became decodable again *on this instance* (queueing at
    /// the survivor + the re-prefill), recorded at prefill time.
    pub requeue_delay_secs: f64,
    /// Crash-requeued samples re-admitted into this instance's decode
    /// slots (the denominator of the recovery-latency mean).
    pub requeues_admitted: u64,
    /// (wall_clock_secs, tokens_out cumulative, live samples) trace rows
    /// for throughput-over-time figures.
    pub trace: Vec<(f64, u64, usize)>,
}

impl InstanceMetrics {
    /// Total instance stage time (sum of the per-stage wall-times).
    pub fn total_secs(&self) -> f64 {
        self.prefill_secs
            + self.draft_secs
            + self.select_secs
            + self.verify_secs
            + self.accept_secs
            + self.commit_secs
            + self.migration_secs
    }

    /// Mean accepted draft tokens per round.
    pub fn mean_accepted(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.drafts_accepted as f64 / self.rounds as f64
        }
    }

    /// Draft token acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafts_proposed == 0 {
            0.0
        } else {
            self.drafts_accepted as f64 / self.drafts_proposed as f64
        }
    }

    /// Decision-overhead fraction: selector time / total (§7.7 WDS).
    pub fn selector_overhead(&self) -> f64 {
        let t = self.total_secs();
        if t == 0.0 {
            0.0
        } else {
            self.select_secs / t
        }
    }

    /// Tokens per second of instance stage time (0 when no time elapsed —
    /// guards the divide for instances that never stepped).
    pub fn throughput(&self) -> f64 {
        let t = self.total_secs();
        if t <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / t
        }
    }

    /// The per-stage wall-times as named `(stage, seconds)` pairs in
    /// canonical order — the §7.7 / Fig 3 stage decomposition consumed
    /// by the trace plane's metrics export and `trace_summary.py`.
    pub fn stage_breakdown(&self) -> [(&'static str, f64); 7] {
        [
            ("prefill", self.prefill_secs),
            ("draft", self.draft_secs),
            ("select", self.select_secs),
            ("verify", self.verify_secs),
            ("accept", self.accept_secs),
            ("commit", self.commit_secs),
            ("migration", self.migration_secs),
        ]
    }
}

/// Transport-protocol fault and recovery counters, shared by both
/// decode planes: `ClusterResult` (simulation) and `GenerationReport`
/// (threaded PJRT driver) embed this one type instead of duplicating
/// the four fields, so the trace plane and every consumer read the
/// same shape regardless of plane.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProtocolCounters {
    /// Stage-2 carrier retransmissions fired (unacked payload resent
    /// after the per-order retransmit timer).
    pub retransmits: u64,
    /// Migration orders aborted after a handshake timeout on an
    /// unreliable transport (victims returned to the source batch).
    pub handshake_aborts: u64,
    /// Messages the (virtual or real) link dropped.
    pub link_drops: u64,
    /// Messages the link duplicated.
    pub link_dups: u64,
}

/// One finished sample's serving latencies (streaming workloads).
///
/// All values are seconds on the plane's clock — virtual seconds in the
/// simulation cluster, wall seconds on the PJRT driver — measured from
/// the sample's *arrival* (submission), not from its admission.
#[derive(Clone, Copy, Debug)]
pub struct SampleLatency {
    /// Arrival → admission into a decode slot (scheduling delay).
    pub queue_secs: f64,
    /// Arrival → first generated token (time-to-first-token).
    pub ttft_secs: f64,
    /// Mean seconds per output token after the first
    /// (time-per-output-token); 0 for single-token responses.
    pub tpot_secs: f64,
}

/// p50/p95/p99 percentile summary over a set of [`SampleLatency`]
/// records. All fields are 0 when no sample carried latency data (e.g.
/// batch-synchronous runs, where every sample arrives at t = 0 and
/// queueing delay is not meaningful).
///
/// Percentiles inherit [`crate::utils::stats::percentile`]'s pinned
/// interpolation rule — `rank = (p / 100) · (len − 1)`, linear between
/// the two nearest order statistics — so a single sample pins every
/// percentile to that sample exactly and no value is invented outside
/// the data range.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencySummary {
    /// Number of samples summarized.
    pub n: usize,
    /// Median queueing delay (arrival → decode slot), seconds.
    pub queue_p50: f64,
    /// 95th-percentile queueing delay, seconds.
    pub queue_p95: f64,
    /// 99th-percentile queueing delay, seconds.
    pub queue_p99: f64,
    /// Median time-to-first-token, seconds.
    pub ttft_p50: f64,
    /// 95th-percentile time-to-first-token, seconds.
    pub ttft_p95: f64,
    /// 99th-percentile time-to-first-token, seconds.
    pub ttft_p99: f64,
    /// Median time-per-output-token, seconds.
    pub tpot_p50: f64,
    /// 95th-percentile time-per-output-token, seconds.
    pub tpot_p95: f64,
    /// 99th-percentile time-per-output-token, seconds.
    pub tpot_p99: f64,
}

impl LatencySummary {
    /// Summarize a batch of per-sample latencies (zeroed when empty).
    ///
    /// Non-finite components (a NaN/inf smuggled in by a degenerate
    /// sample) are squashed to 0 before ranking so one bad record cannot
    /// poison every percentile above its rank.
    pub fn from_samples(lat: &[SampleLatency]) -> Self {
        if lat.is_empty() {
            return LatencySummary::default();
        }
        let clean = |v: f64| if v.is_finite() { v } else { 0.0 };
        let queue: Vec<f64> = lat.iter().map(|l| clean(l.queue_secs)).collect();
        let ttft: Vec<f64> = lat.iter().map(|l| clean(l.ttft_secs)).collect();
        let tpot: Vec<f64> = lat.iter().map(|l| clean(l.tpot_secs)).collect();
        LatencySummary {
            n: lat.len(),
            queue_p50: stats::percentile(&queue, 50.0),
            queue_p95: stats::percentile(&queue, 95.0),
            queue_p99: stats::percentile(&queue, 99.0),
            ttft_p50: stats::percentile(&ttft, 50.0),
            ttft_p95: stats::percentile(&ttft, 95.0),
            ttft_p99: stats::percentile(&ttft, 99.0),
            tpot_p50: stats::percentile(&tpot, 50.0),
            tpot_p95: stats::percentile(&tpot, 95.0),
            tpot_p99: stats::percentile(&tpot, 99.0),
        }
    }
}

/// Scoped stage timer: `let _t = Stage::new(&mut m.draft_secs);` adds the
/// elapsed time on drop. (Plain function style to avoid borrow juggling.)
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Seconds since start (or the previous lap); resets the lap origin.
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.0).as_secs_f64();
        self.0 = now;
        dt
    }

    /// Seconds since start (or the previous lap), without resetting.
    pub fn elapsed(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_divide_safely() {
        let m = InstanceMetrics::default();
        assert_eq!(m.mean_accepted(), 0.0);
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn overhead_fraction() {
        let m = InstanceMetrics {
            select_secs: 1.0,
            verify_secs: 9.0,
            ..Default::default()
        };
        assert!((m.selector_overhead() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn latency_summary_empty_is_zeroed() {
        // The empty sample set must not divide, index, or NaN anything —
        // a crashed-out or fully-refused run reports all-zero latencies.
        let s = LatencySummary::from_samples(&[]);
        assert_eq!(s, LatencySummary::default());
        assert_eq!(s.n, 0);
        assert_eq!(s.ttft_p99, 0.0);
        assert_eq!(s.queue_p50, 0.0);
        assert_eq!(s.tpot_p95, 0.0);
    }

    #[test]
    fn latency_summary_single_sample_pins_every_percentile() {
        // One sample: every percentile is that sample, exactly.
        let one = SampleLatency { queue_secs: 0.5, ttft_secs: 1.25, tpot_secs: 0.02 };
        let s = LatencySummary::from_samples(&[one]);
        assert_eq!(s.n, 1);
        for v in [s.queue_p50, s.queue_p95, s.queue_p99] {
            assert_eq!(v, 0.5);
        }
        for v in [s.ttft_p50, s.ttft_p95, s.ttft_p99] {
            assert_eq!(v, 1.25);
        }
        for v in [s.tpot_p50, s.tpot_p95, s.tpot_p99] {
            assert_eq!(v, 0.02);
        }
    }

    #[test]
    fn latency_summary_percentiles_ordered() {
        let lat: Vec<SampleLatency> = (0..100)
            .map(|i| SampleLatency {
                queue_secs: i as f64,
                ttft_secs: i as f64 + 1.0,
                tpot_secs: 0.01 * i as f64,
            })
            .collect();
        let s = LatencySummary::from_samples(&lat);
        assert_eq!(s.n, 100);
        assert!(s.queue_p50 <= s.queue_p95 && s.queue_p95 <= s.queue_p99);
        assert!(s.ttft_p50 <= s.ttft_p95 && s.ttft_p95 <= s.ttft_p99);
        assert!((s.queue_p50 - 49.5).abs() < 1e-9);
        // TTFT includes the queueing delay by construction here.
        assert!(s.ttft_p50 > s.queue_p50);
    }

    #[test]
    fn latency_summary_squashes_non_finite_components() {
        // A degenerate record (e.g. a NaN TPOT from an upstream bug) must
        // not poison the percentiles of the healthy samples around it.
        let lat = vec![
            SampleLatency { queue_secs: 0.1, ttft_secs: 0.2, tpot_secs: 0.01 },
            SampleLatency {
                queue_secs: f64::NAN,
                ttft_secs: f64::INFINITY,
                tpot_secs: f64::NAN,
            },
            SampleLatency { queue_secs: 0.3, ttft_secs: 0.4, tpot_secs: 0.02 },
        ];
        let s = LatencySummary::from_samples(&lat);
        assert_eq!(s.n, 3);
        for v in [
            s.queue_p50, s.queue_p95, s.queue_p99, s.ttft_p50, s.ttft_p95,
            s.ttft_p99, s.tpot_p50, s.tpot_p95, s.tpot_p99,
        ] {
            assert!(v.is_finite(), "{v}");
        }
        assert!(s.queue_p99 <= 0.3 && s.ttft_p99 <= 0.4 && s.tpot_p99 <= 0.02);
    }

    #[test]
    fn stopwatch_laps_monotone() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let a = sw.lap();
        assert!(a >= 0.002);
        let b = sw.lap();
        assert!(b < a);
    }
}
