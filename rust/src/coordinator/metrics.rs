//! Per-instance stage timing and counters.
//!
//! Feeds Fig 3 (stage breakdown), Fig 5/14 (throughput-over-time curves)
//! and the §7.7 overhead analysis (WDS = `select_secs`, SRD lives in the
//! driver, SM = `migration_secs`).

use std::time::Instant;

#[derive(Clone, Debug, Default)]
pub struct InstanceMetrics {
    // ---- stage wall-times (seconds) ----
    pub prefill_secs: f64,
    pub draft_secs: f64,
    pub select_secs: f64,
    pub verify_secs: f64,
    pub accept_secs: f64,
    pub commit_secs: f64,
    pub migration_secs: f64,
    // ---- counters ----
    pub rounds: u64,
    pub tokens_out: u64,
    pub drafts_proposed: u64,
    pub drafts_accepted: u64,
    pub samples_finished: u64,
    pub samples_migrated_in: u64,
    pub samples_migrated_out: u64,
    /// (wall_clock_secs, tokens_out cumulative, live samples) trace rows
    /// for throughput-over-time figures.
    pub trace: Vec<(f64, u64, usize)>,
}

impl InstanceMetrics {
    pub fn total_secs(&self) -> f64 {
        self.prefill_secs
            + self.draft_secs
            + self.select_secs
            + self.verify_secs
            + self.accept_secs
            + self.commit_secs
            + self.migration_secs
    }

    /// Mean accepted draft tokens per round.
    pub fn mean_accepted(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.drafts_accepted as f64 / self.rounds as f64
        }
    }

    /// Draft token acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafts_proposed == 0 {
            0.0
        } else {
            self.drafts_accepted as f64 / self.drafts_proposed as f64
        }
    }

    /// Decision-overhead fraction: selector time / total (§7.7 WDS).
    pub fn selector_overhead(&self) -> f64 {
        let t = self.total_secs();
        if t == 0.0 {
            0.0
        } else {
            self.select_secs / t
        }
    }

    /// Tokens per second of instance stage time (0 when no time elapsed —
    /// guards the divide for instances that never stepped).
    pub fn throughput(&self) -> f64 {
        let t = self.total_secs();
        if t <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / t
        }
    }
}

/// Scoped stage timer: `let _t = Stage::new(&mut m.draft_secs);` adds the
/// elapsed time on drop. (Plain function style to avoid borrow juggling.)
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.0).as_secs_f64();
        self.0 = now;
        dt
    }

    pub fn elapsed(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_divide_safely() {
        let m = InstanceMetrics::default();
        assert_eq!(m.mean_accepted(), 0.0);
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn overhead_fraction() {
        let m = InstanceMetrics {
            select_secs: 1.0,
            verify_secs: 9.0,
            ..Default::default()
        };
        assert!((m.selector_overhead() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_laps_monotone() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let a = sw.lap();
        assert!(a >= 0.002);
        let b = sw.lap();
        assert!(b < a);
    }
}
