//! Workload-aware drafting-strategy selection (paper §5).
//!
//! Chooses the draft-token budget `n` for one speculative step of one
//! instance, maximizing `al(n) / t_sd(n)` (Eq. 2) where:
//!
//! * `al(n)`  = predicted accepted tokens = Σ node weights of the top-n
//!   connected selection across the batch's candidate trees (§5.2, Fig 8);
//! * `t_sd(n)` = predicted step time from the `TsdPredictor` with
//!   `N_draft = Σ per-sample n` and `N_seq` = cumulative committed length.
//!
//! The *layer-level search* walks n upward using the incremental property
//! `S(n+1) = S(n) ∪ {u_max}` (each step only needs the next frontier
//! weight) and early-stops via the sugar-water inequality (Eq. 3): once
//! `Δal/Δt_sd < al(n)/t_sd(n)` the objective can only fall, so after
//! `patience` consecutive decreases the search terminates.
//!
//! **Pinned edge-case behavior** (guarded by the unit tests below —
//! drafting policies above this search rely on every line of it):
//!
//! * **Empty `trees` slice**: `batch` clamps to 1, every Δal is 0, and
//!   the search returns `n = 1` with `predicted_al = 0.0` — never a
//!   panic, never NaN.
//! * **`max_n == 0`**: silently clamped to 1; the search always
//!   evaluates at least `n = 1` and `1 ≤ choice.n ≤ max(max_n, 1)`.
//! * **`patience = 0`**: legal — the search stops after the *second*
//!   consecutive decrease (`decreases > patience` with the counter
//!   incremented first), having still evaluated every n up to that
//!   point; the returned choice is unaffected on unimodal objectives.
//! * **NaN-poisoned `TsdPredictor`** (NaN observations → NaN
//!   regression coefficients): `TsdPredictor::eval` ends in
//!   `.max(1e-6)`, and IEEE `max` discards a NaN operand — so every
//!   prediction clamps to the 1e-6 floor, the search sees a flat
//!   (minimal) step time and returns the largest-`al` budget with
//!   finite objectives. Callers never see a NaN budget, prediction or
//!   objective, and nothing panics (the normal-equations solver treats
//!   NaN pivots as non-candidates). Were an objective ever NaN anyway,
//!   the `obj > best_obj` comparison is false for NaN, so the finite
//!   `{n: 1, predicted_al: 0.0, predicted_tsd: 1.0}` default would come
//!   back — NaN cannot escape this module either way.

use crate::config::SelectorConfig;
use crate::spec::tree::CandidateTree;

use super::predictor::TsdPredictor;

/// Outcome of one strategy search.
#[derive(Clone, Debug)]
pub struct StrategyChoice {
    /// Chosen per-sample draft token budget (tree tokens incl. root).
    pub n: usize,
    /// Predicted accepted tokens at the chosen n (batch total).
    pub predicted_al: f64,
    /// Predicted step seconds at the chosen n.
    pub predicted_tsd: f64,
    /// Number of candidate n values actually evaluated (≤ max_n; shows
    /// pruning effectiveness).
    pub evaluated: usize,
}

/// Incremental weight streams per sample: `inc[s][k]` = weight of the
/// (k+1)-th node greedily added to sample s's selection.
fn incremental_weights(trees: &[&CandidateTree], max_n: usize) -> Vec<Vec<f64>> {
    trees
        .iter()
        .map(|t| {
            let order = t.select_top_n(max_n.min(t.len()));
            order.iter().map(|&i| t.nodes[i].w as f64).collect()
        })
        .collect()
}

/// Layer-level search for the near-optimal per-sample budget `n`.
///
/// `n_seq`: batch cumulative committed sequence length (KV-load feature);
/// `trees`: one candidate tree per live sample.
pub fn select_strategy(
    cfg: &SelectorConfig,
    tsd: &mut TsdPredictor,
    trees: &[&CandidateTree],
    n_seq: usize,
    max_n: usize,
) -> StrategyChoice {
    let batch = trees.len().max(1);
    let max_n = max_n.max(1);
    let inc = incremental_weights(trees, max_n);

    let mut best = StrategyChoice { n: 1, predicted_al: 0.0, predicted_tsd: 1.0, evaluated: 0 };
    let mut best_obj = f64::NEG_INFINITY;
    let mut al = 0.0f64;
    let mut decreases = 0usize;
    let mut evaluated = 0usize;

    for n in 1..=max_n {
        // Δal for this n: each sample adds its n-th greedy node (if any).
        let mut delta = 0.0;
        for s in inc.iter() {
            if n <= s.len() {
                delta += s[n - 1];
            }
        }
        al += delta;
        let n_draft = batch * n;
        let t = tsd.predict(n_seq, n_draft);
        let obj = al / t;
        evaluated += 1;
        if obj > best_obj {
            best_obj = obj;
            best = StrategyChoice { n, predicted_al: al, predicted_tsd: t, evaluated };
            decreases = 0;
        } else {
            // Sugar-water early stop (Eq. 3): objective decreased; Δal is
            // non-increasing (greedy max-weight) and Δt_sd non-decreasing
            // (regression is affine-increasing), so after `patience`
            // consecutive decreases no larger objective can appear.
            decreases += 1;
            if decreases > cfg.patience {
                break;
            }
        }
    }
    best.evaluated = evaluated;
    best
}

/// Exhaustive argmax over all n (oracle for tests & Table 1).
pub fn select_exhaustive(
    tsd: &mut TsdPredictor,
    trees: &[&CandidateTree],
    n_seq: usize,
    max_n: usize,
) -> StrategyChoice {
    let batch = trees.len().max(1);
    let inc = incremental_weights(trees, max_n);
    let mut best = StrategyChoice { n: 1, predicted_al: 0.0, predicted_tsd: 1.0, evaluated: max_n };
    let mut best_obj = f64::NEG_INFINITY;
    let mut al = 0.0;
    for n in 1..=max_n {
        for s in inc.iter() {
            if n <= s.len() {
                al += s[n - 1];
            }
        }
        let t = tsd.predict(n_seq, batch * n);
        let obj = al / t;
        if obj > best_obj {
            best_obj = obj;
            best = StrategyChoice { n, predicted_al: al, predicted_tsd: t, evaluated: max_n };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::rng::Rng;

    fn fitted_tsd(c1: f64, c2: f64) -> TsdPredictor {
        let mut t = TsdPredictor::new(1, 1);
        for s in 0..30 {
            for d in 1..30 {
                t.observe(s * 64, d, 0.003 + c1 * (s * 64) as f64 + c2 * d as f64);
            }
        }
        t.refit();
        t
    }

    fn random_tree(rng: &mut Rng, size: usize) -> CandidateTree {
        let mut t = CandidateTree::new(0);
        for _ in 1..size {
            let parent = rng.below(t.len());
            let o = 0.2 + 0.8 * rng.f32();
            t.add_child(parent, rng.below(64) as i32, o);
        }
        for n in &mut t.nodes {
            n.w = n.dl; // identity F (monotone)
        }
        t
    }

    #[test]
    fn search_matches_exhaustive() {
        // Property: pruned layer-level search == exhaustive argmax.
        crate::testutil::check("selector==oracle", 100, |rng| {
            let mut tsd = fitted_tsd(1e-7, 5e-5);
            let cfg = SelectorConfig { patience: 2, ..Default::default() };
            let n_trees = rng.range(1, 4);
            let trees: Vec<CandidateTree> = (0..n_trees)
                .map(|_| {
                    let size = rng.range(2, 30);
                    random_tree(rng, size)
                })
                .collect();
            let refs: Vec<&CandidateTree> = trees.iter().collect();
            let n_seq = rng.below(2000);
            let a = select_strategy(&cfg, &mut tsd, &refs, n_seq, 24);
            let b = select_exhaustive(&mut tsd, &refs, n_seq, 24);
            assert_eq!(a.n, b.n, "pruned={} oracle={}", a.n, b.n);
        });
    }

    #[test]
    fn pruning_reduces_evaluations() {
        let mut tsd = fitted_tsd(1e-7, 2e-3); // steep verify cost → small n*
        let cfg = SelectorConfig { patience: 2, ..Default::default() };
        let mut rng = Rng::new(1);
        let tree = random_tree(&mut rng, 40);
        let choice = select_strategy(&cfg, &mut tsd, &[&tree], 512, 40);
        assert!(choice.evaluated < 40, "no pruning happened: {choice:?}");
        assert!(choice.n < 20);
    }

    #[test]
    fn expensive_verification_prefers_small_n() {
        let cfg = SelectorConfig::default();
        let mut rng = Rng::new(2);
        let tree = random_tree(&mut rng, 32);
        let mut cheap = fitted_tsd(1e-8, 1e-6);
        let mut dear = fitted_tsd(1e-8, 5e-3);
        let n_cheap = select_strategy(&cfg, &mut cheap, &[&tree], 256, 32).n;
        let n_dear = select_strategy(&cfg, &mut dear, &[&tree], 256, 32).n;
        assert!(
            n_dear <= n_cheap,
            "dear verify should not pick larger n ({n_dear} vs {n_cheap})"
        );
    }

    #[test]
    fn larger_batch_shrinks_per_sample_budget() {
        // With per-token verify cost, 8 samples saturate the step budget
        // sooner than 1 sample (the paper's high-workload regime).
        let cfg = SelectorConfig::default();
        let mut rng = Rng::new(3);
        let trees: Vec<CandidateTree> = (0..8).map(|_| random_tree(&mut rng, 32)).collect();
        let solo = vec![&trees[0]];
        let all: Vec<&CandidateTree> = trees.iter().collect();
        let mut tsd = fitted_tsd(1e-7, 2e-4);
        let n_solo = select_strategy(&cfg, &mut tsd, &solo, 256, 32).n;
        let mut tsd2 = fitted_tsd(1e-7, 2e-4);
        let n_all = select_strategy(&cfg, &mut tsd2, &all, 2048, 32).n;
        assert!(n_all <= n_solo, "batch=8 chose n={n_all} > solo n={n_solo}");
    }

    #[test]
    fn al_prediction_is_prefix_sum_of_weights() {
        let mut rng = Rng::new(4);
        let tree = random_tree(&mut rng, 10);
        let mut tsd = fitted_tsd(1e-8, 1e-5);
        let cfg = SelectorConfig { patience: 99, ..Default::default() };
        let choice = select_strategy(&cfg, &mut tsd, &[&tree], 64, 10);
        let order = tree.select_top_n(choice.n);
        let manual: f64 = order.iter().map(|&i| tree.nodes[i].w as f64).sum();
        assert!((choice.predicted_al - manual).abs() < 1e-9);
    }

    #[test]
    fn single_node_tree_picks_n1() {
        let tree = CandidateTree::new(5);
        let mut tsd = fitted_tsd(1e-8, 1e-5);
        let cfg = SelectorConfig::default();
        let c = select_strategy(&cfg, &mut tsd, &[&tree], 0, 16);
        assert_eq!(c.n, 1);
    }

    #[test]
    fn empty_trees_slice_returns_default() {
        // An idle-batch call must not panic: batch clamps to 1, al stays
        // 0, and the n=1 default comes back with finite predictions.
        let mut tsd = fitted_tsd(1e-7, 5e-5);
        let cfg = SelectorConfig::default();
        let c = select_strategy(&cfg, &mut tsd, &[], 0, 16);
        assert_eq!(c.n, 1);
        assert_eq!(c.predicted_al, 0.0);
        assert!(c.predicted_tsd.is_finite());
        assert!(c.evaluated >= 1);
    }

    #[test]
    fn max_n_zero_is_clamped_to_one() {
        let mut rng = Rng::new(5);
        let tree = random_tree(&mut rng, 16);
        let mut tsd = fitted_tsd(1e-7, 5e-5);
        let cfg = SelectorConfig::default();
        let c = select_strategy(&cfg, &mut tsd, &[&tree], 128, 0);
        assert_eq!(c.n, 1, "max_n = 0 must clamp to a single-token budget");
        assert_eq!(c.evaluated, 1);
        assert!(c.predicted_al > 0.0);
    }

    #[test]
    fn zero_patience_still_finds_unimodal_optimum() {
        // patience = 0 stops after the second consecutive decrease; on
        // the unimodal Eq-2 objective that cannot skip the argmax.
        let mut rng = Rng::new(6);
        let tree = random_tree(&mut rng, 32);
        let cfg0 = SelectorConfig { patience: 0, ..Default::default() };
        let mut tsd_a = fitted_tsd(1e-7, 2e-4);
        let a = select_strategy(&cfg0, &mut tsd_a, &[&tree], 512, 32);
        let mut tsd_b = fitted_tsd(1e-7, 2e-4);
        let b = select_exhaustive(&mut tsd_b, &[&tree], 512, 32);
        assert_eq!(a.n, b.n, "patience=0 missed the optimum");
        assert!(a.evaluated <= 32);
    }

    #[test]
    fn nan_predictor_yields_finite_choice() {
        // NaN observations poison the regression coefficients, but
        // eval's `.max(1e-6)` floor discards the NaN (IEEE max), so the
        // search sees a flat minimal step time, never panics, and
        // returns the largest-al budget with finite predictions.
        let mut tsd = TsdPredictor::new(1, 1);
        for s in 0..10 {
            for d in 1..10 {
                tsd.observe(s * 64, d, f64::NAN);
            }
        }
        tsd.refit();
        assert!(tsd.coefficients().iter().all(|c| c.is_nan()));
        assert_eq!(tsd.predict_exact(256, 8), 1e-6, "floor must absorb the NaN");
        let mut rng = Rng::new(7);
        let tree = random_tree(&mut rng, 16);
        let cfg = SelectorConfig::default();
        let c = select_strategy(&cfg, &mut tsd, &[&tree], 256, 16);
        assert!(c.n >= 1 && c.n <= 16);
        assert!(!c.predicted_al.is_nan());
        assert!(c.predicted_tsd == 1e-6 && !c.predicted_tsd.is_nan());
        // Flat t_sd ⇒ the objective grows with al ⇒ the full budget wins.
        assert_eq!(c.n, 16);
        let o = select_exhaustive(&mut tsd, &[&tree], 256, 16);
        assert_eq!(o.n, c.n);
        assert!(!o.predicted_tsd.is_nan());
    }
}
