//! Pluggable drafting control plane: the [`DraftPolicy`] trait and its
//! three shipped implementations.
//!
//! The paper's §5 selector ([`crate::coordinator::selector`]) picks a
//! draft budget from *current* workload stats with a fixed predictor.
//! The related work goes further — "Learning to Draft" adapts the
//! speculative configuration online from acceptance feedback, and
//! EfficientRollout adds a system-aware *self*-speculative mode that
//! needs no separate draft model. This module makes the strategy
//! selection a policy slot on [`crate::coordinator::core::InstanceCore`]:
//!
//! * [`StaticSelector`] — the default: delegates every decision to
//!   [`selector::select_strategy`], bit-for-bit identical to the
//!   pre-policy behavior (pinned by `tests/policy_suite.rs`).
//! * [`BanditPolicy`] — a contextual UCB bandit over discretized
//!   workload buckets × candidate budget arms, learning per-step from
//!   realized accepted tokens and step seconds. Arm 0 *delegates to the
//!   §5 selector*, so the learned policy's floor is the static
//!   behavior; the fixed-`n` arms let it react to drafter-staleness
//!   shifts faster than the selector's refit cadence. Forgetting at
//!   every RLHF weight-update barrier ([`PolicyCtx::model_version`]
//!   bump) re-opens exploration so it re-converges after the PR-8
//!   acceptance decay.
//! * [`SelfSpecStrategy`] — skip-layer self-speculative drafting: the
//!   budget search is unchanged, but instances on the configured tiers
//!   swap their backend to [`crate::sim::cost_model::CostModel::self_spec`]
//!   (draft levels run a configured fraction of the target's layers —
//!   no separate draft model) with the matching
//!   [`crate::sim::acceptance::AcceptanceModel::self_draft`] profile.
//!
//! **Determinism contract.** A policy must be a pure function of its
//! construction seed and the sequence of `choose`/`feedback` calls it
//! has seen: no wall clock, no global RNG, no shared state. The bandit
//! draws only from its private stream seeded
//! `seed ^ POLICY_SEED_SALT`, forked per instance — so runs replay
//! bit-for-bit at any engine thread count and shard count
//! (`tests/policy_suite.rs` pins replay plus the [`DraftPolicy::digest`]
//! state fingerprint). Policies must also be `Send`: instances step on
//! the parallel engine's worker threads.
//!
//! **Adding a policy**: implement [`DraftPolicy`] (only `choose` and
//! `name` are required), add a [`PolicyKind`] variant + `[policy] kind`
//! spelling, and construct it in [`PolicyConfig::build`]. Keep the
//! three contracts: (1) deterministic per the paragraph above; (2) if
//! your policy is not the configured default it must not perturb
//! `kind = "static"` runs at all; (3) report decisions through
//! [`DraftPolicy::decision`] rather than printing — the trace plane
//! turns them into per-instance instants.

use anyhow::{anyhow, bail, Result};

use crate::config::SelectorConfig;
use crate::coordinator::predictor::TsdPredictor;
use crate::coordinator::selector::{self, StrategyChoice};
use crate::spec::tree::CandidateTree;
use crate::utils::rng::Rng;

/// Salt for the policy plane's private RNG stream
/// (`seed ^ POLICY_SEED_SALT`, forked per instance) — disjoint from the
/// workload, admission and loop streams by construction.
pub const POLICY_SEED_SALT: u64 = 0x00BA_4D17_5EED;

/// Workload context carried into every policy decision. Pure
/// arithmetic over instance state — constructing it draws no RNG, so
/// the static path stays bit-inert.
#[derive(Clone, Copy, Debug)]
pub struct PolicyCtx {
    /// Live samples in this round's batch (= candidate trees).
    pub batch: usize,
    /// Batch cumulative committed sequence length (KV-load feature).
    pub n_seq: usize,
    /// Hardware tier index of the instance (0 on homogeneous fleets).
    pub tier: usize,
    /// Parked + queued samples behind the batch (pressure signal).
    pub backlog: usize,
    /// RLHF target-model version last synced to this instance. A bump
    /// means a weight-update barrier ran: acceptance decayed and
    /// learned policies should forget toward re-exploration.
    pub model_version: u64,
}

/// Borrowed inputs a policy needs to run (or delegate to) the §5
/// budget search for one speculative round.
pub struct SelectArgs<'a> {
    /// Selector knobs (patience, refit cadence).
    pub cfg: &'a SelectorConfig,
    /// The instance's online `t_sd` regression (bucket-cached predict).
    pub tsd: &'a mut TsdPredictor,
    /// One candidate tree per live sample, node weights already set.
    pub trees: &'a [&'a CandidateTree],
    /// Batch cumulative committed sequence length.
    pub n_seq: usize,
    /// Largest per-sample budget the backend supports.
    pub max_n: usize,
}

/// Compact summary of one learned decision, buffered on the instance
/// and emitted by the trace plane as a per-instance instant.
#[derive(Clone, Copy, Debug)]
pub struct PolicyDecision {
    /// Chosen per-sample draft budget.
    pub n: usize,
    /// Chosen arm (0 = delegated to the §5 selector).
    pub arm: usize,
    /// Discretized context bucket the decision was scored in.
    pub bucket: usize,
    /// Posterior mean reward of the chosen arm before this pull
    /// (tokens/sec; 0 for a never-pulled arm).
    pub mean: f64,
    /// The arm was picked for exploration (unpulled in this bucket).
    pub explore: bool,
}

/// A pluggable drafting-strategy policy (see the module docs for the
/// determinism contract). `Send` because instances step on the
/// parallel engine's worker threads.
pub trait DraftPolicy: Send {
    /// Pick the per-sample draft budget for one speculative round.
    fn choose(&mut self, ctx: &PolicyCtx, args: SelectArgs<'_>) -> StrategyChoice;

    /// Observe the realized outcome of the round `choose` configured:
    /// `accepted` draft tokens landed in `step_secs` virtual seconds.
    /// Default: no learning.
    fn feedback(&mut self, _ctx: &PolicyCtx, _accepted: usize, _step_secs: f64) {}

    /// Summary of the most recent decision for the trace plane. `None`
    /// (the default) emits nothing — the static selector stays silent
    /// so traced `kind = "static"` runs keep the pre-policy schema.
    fn decision(&self) -> Option<PolicyDecision> {
        None
    }

    /// Deterministic fingerprint of the learned state — equal digests
    /// after equal `(seed, call sequence)` histories. `0` for
    /// stateless policies.
    fn digest(&self) -> u64 {
        0
    }

    /// Short policy id for reports and traces.
    fn name(&self) -> &'static str;
}

/// The default policy: every decision delegates to
/// [`selector::select_strategy`] with untouched arguments —
/// bit-for-bit the pre-policy behavior.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticSelector;

impl DraftPolicy for StaticSelector {
    fn choose(&mut self, _ctx: &PolicyCtx, args: SelectArgs<'_>) -> StrategyChoice {
        selector::select_strategy(args.cfg, args.tsd, args.trees, args.n_seq, args.max_n)
    }

    fn name(&self) -> &'static str {
        "static"
    }
}

/// Skip-layer self-speculative mode. The *decision* side is the plain
/// §5 search (the swapped cost/acceptance models flow in through the
/// instance's own online predictors); the *execution* side is the
/// per-tier backend swap applied at cluster construction — see
/// [`PolicyConfig::selfspec_tier`] and
/// [`crate::sim::cost_model::CostModel::self_spec`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SelfSpecStrategy;

impl DraftPolicy for SelfSpecStrategy {
    fn choose(&mut self, _ctx: &PolicyCtx, args: SelectArgs<'_>) -> StrategyChoice {
        selector::select_strategy(args.cfg, args.tsd, args.trees, args.n_seq, args.max_n)
    }

    fn name(&self) -> &'static str {
        "selfspec"
    }
}

/// Fixed per-sample budgets backing arms `1..`; arm 0 delegates to the
/// §5 selector. Entries above the backend's `max_n` are masked out per
/// decision.
const ARM_GRID: [usize; 10] = [1, 2, 4, 6, 8, 12, 16, 24, 32, 48];
/// Arms per context bucket: delegate + the grid.
const N_ARMS: usize = 1 + ARM_GRID.len();
/// `floor(log2(batch))` buckets, clamped to 0..=6 (batch ≥ 64 shares
/// the top bucket).
const BATCH_BUCKETS: usize = 7;
/// Per-sample committed-length buckets of 512 tokens, clamped to 0..=3.
const LEN_BUCKETS: usize = 4;
/// Total context buckets.
const N_BUCKETS: usize = BATCH_BUCKETS * LEN_BUCKETS;

/// Discretize a decision context into its bucket index.
fn context_bucket(ctx: &PolicyCtx) -> usize {
    let b = ctx.batch.max(1);
    let batch_bucket = ((usize::BITS - 1 - b.leading_zeros()) as usize).min(BATCH_BUCKETS - 1);
    let len_bucket = (ctx.n_seq / b / 512).min(LEN_BUCKETS - 1);
    batch_bucket * LEN_BUCKETS + len_bucket
}

/// Decayed pull statistics of one `(bucket, arm)` cell.
#[derive(Clone, Copy, Debug, Default)]
struct ArmStats {
    /// Effective pull count (decayed by the window cap and forgetting).
    count: f64,
    /// Decayed reward sum (tokens/sec).
    sum: f64,
}

/// One FNV-1a mixing step (digest helper).
fn fnv(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Contextual UCB bandit over workload buckets × budget arms (module
/// docs). Learned state is per-instance; the only randomness is a tiny
/// deterministic tie-break jitter from the private salted stream.
pub struct BanditPolicy {
    /// UCB exploration coefficient.
    c: f64,
    /// Multiplier applied to every statistic at a model-version bump.
    forget: f64,
    /// Effective-sample cap per cell (sliding-window forgetting).
    window: f64,
    /// Private tie-break stream (`seed ^ POLICY_SEED_SALT`, forked per
    /// instance).
    rng: Rng,
    /// Flat `[bucket * N_ARMS + arm]` statistics.
    stats: Vec<ArmStats>,
    /// Decayed total pulls (the UCB `ln T` term).
    total: f64,
    /// Decayed global reward count (exploration-width scale).
    gcount: f64,
    /// Decayed global reward sum.
    gsum: f64,
    /// Last model version seen (forgetting trigger).
    last_version: u64,
    /// `(bucket, arm)` of the decision awaiting feedback.
    pending: Option<(usize, usize)>,
    /// Most recent decision summary (trace plane).
    last: Option<PolicyDecision>,
}

impl BanditPolicy {
    /// Bandit for instance `instance` of a run seeded `seed`, with the
    /// `[policy]` knobs of `cfg` (non-finite knobs fall back to the
    /// defaults; see [`PolicyConfig`]).
    pub fn new(cfg: &PolicyConfig, seed: u64, instance: usize) -> Self {
        let mut root = Rng::new(seed ^ POLICY_SEED_SALT);
        let rng = root.fork(instance as u64 + 1);
        let d = PolicyConfig::default();
        BanditPolicy {
            c: if cfg.bandit_c.is_finite() { cfg.bandit_c.max(0.0) } else { d.bandit_c },
            forget: if cfg.forget.is_finite() { cfg.forget.clamp(0.0, 1.0) } else { d.forget },
            window: if cfg.window.is_finite() { cfg.window.max(1.0) } else { d.window },
            rng,
            stats: vec![ArmStats::default(); N_BUCKETS * N_ARMS],
            total: 0.0,
            gcount: 0.0,
            gsum: 0.0,
            last_version: 0,
            pending: None,
            last: None,
        }
    }

    /// Mean reward of `(bucket, arm)` (0 for a never-pulled cell).
    fn mean(&self, bucket: usize, arm: usize) -> f64 {
        let s = &self.stats[bucket * N_ARMS + arm];
        if s.count > 0.0 {
            s.sum / s.count
        } else {
            0.0
        }
    }
}

impl DraftPolicy for BanditPolicy {
    fn choose(&mut self, ctx: &PolicyCtx, args: SelectArgs<'_>) -> StrategyChoice {
        // A weight-update barrier ran since the last decision: decay
        // everything toward re-exploration (the acceptance process the
        // statistics were learned on no longer exists).
        if ctx.model_version != self.last_version {
            self.last_version = ctx.model_version;
            let f = self.forget;
            for s in self.stats.iter_mut() {
                s.count *= f;
                s.sum *= f;
            }
            self.total *= f;
            self.gcount *= f;
            self.gsum *= f;
        }
        let bucket = context_bucket(ctx);
        let max_n = args.max_n.max(1);
        let scale = if self.gcount > 0.0 { (self.gsum / self.gcount).abs().max(1e-9) } else { 1.0 };
        let lnt = (self.total + 1.0).ln();
        let mut best_arm = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        let mut explore = false;
        for arm in 0..N_ARMS {
            if arm > 0 && ARM_GRID[arm - 1] > max_n {
                continue; // budget arm the backend cannot honor
            }
            let s = &self.stats[bucket * N_ARMS + arm];
            // Deterministic sub-resolution jitter: breaks exact score
            // ties without ever outweighing a real reward difference.
            let jitter = self.rng.f64() * 1e-9 * scale;
            let (score, exploring) = if s.count < 1.0 {
                // Unpulled (or forgotten-below-one) cell: explore
                // first, lowest arm index first.
                (f64::MAX / 2.0 - arm as f64, true)
            } else {
                (s.sum / s.count + self.c * scale * (lnt / s.count).sqrt() + jitter, false)
            };
            if score > best_score {
                best_score = score;
                best_arm = arm;
                explore = exploring;
            }
        }
        let mean = self.mean(bucket, best_arm);
        self.pending = Some((bucket, best_arm));
        let choice = if best_arm == 0 {
            selector::select_strategy(args.cfg, args.tsd, args.trees, args.n_seq, args.max_n)
        } else {
            let n = ARM_GRID[best_arm - 1].min(max_n);
            StrategyChoice { n, predicted_al: 0.0, predicted_tsd: 1.0, evaluated: 0 }
        };
        self.last = Some(PolicyDecision { n: choice.n, arm: best_arm, bucket, mean, explore });
        choice
    }

    fn feedback(&mut self, _ctx: &PolicyCtx, accepted: usize, step_secs: f64) {
        let Some((bucket, arm)) = self.pending.take() else { return };
        let r = accepted as f64 / step_secs.max(1e-9);
        let s = &mut self.stats[bucket * N_ARMS + arm];
        s.count += 1.0;
        s.sum += r;
        if s.count > self.window {
            // Sliding-window cap: keeps the cell adaptive to slow
            // drift between barriers.
            let k = self.window / s.count;
            s.count *= k;
            s.sum *= k;
        }
        self.total += 1.0;
        self.gcount += 1.0;
        self.gsum += r;
        let gcap = 4.0 * self.window;
        if self.gcount > gcap {
            let k = gcap / self.gcount;
            self.gcount *= k;
            self.gsum *= k;
        }
    }

    fn decision(&self) -> Option<PolicyDecision> {
        self.last
    }

    fn digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for s in &self.stats {
            h = fnv(h, s.count.to_bits());
            h = fnv(h, s.sum.to_bits());
        }
        h = fnv(h, self.total.to_bits());
        h = fnv(h, self.last_version);
        h
    }

    fn name(&self) -> &'static str {
        "bandit"
    }
}

/// Which [`DraftPolicy`] the `[policy]` section selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// [`StaticSelector`] — the default, bit-inert.
    Static,
    /// [`BanditPolicy`] — contextual UCB learning per step.
    Bandit,
    /// [`SelfSpecStrategy`] — skip-layer self-drafting backend swap.
    SelfSpec,
}

/// `[policy]` config section: the drafting control plane's knobs.
/// `kind = "static"` (the default) replays bit-identical to the
/// pre-policy scheduler on every golden preset — the other knobs are
/// then never read.
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    /// Which policy every instance runs.
    pub kind: PolicyKind,
    /// Bandit: UCB exploration coefficient (× the running mean reward).
    pub bandit_c: f64,
    /// Bandit: statistic multiplier at each weight-update barrier
    /// (0 = full reset, 1 = never forget).
    pub forget: f64,
    /// Bandit: effective-sample cap per (bucket, arm) cell.
    pub window: f64,
    /// Self-spec: fraction of the target's layers each draft level
    /// runs (sets the draft cost — see
    /// [`crate::sim::cost_model::CostModel::self_spec`]).
    pub self_draft_frac: f64,
    /// Self-spec: draft-confidence penalty of skip-layer drafting vs a
    /// distilled head (see
    /// [`crate::sim::acceptance::AcceptanceModel::self_draft`]).
    pub self_accept_penalty: f64,
    /// Self-spec: comma-separated tier names that swap to the
    /// self-drafting backend; empty = every tier (hetero fleets can
    /// mix self-drafting and classic-SSM tiers).
    pub selfspec_tiers: String,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            kind: PolicyKind::Static,
            bandit_c: 0.4,
            forget: 0.25,
            window: 256.0,
            self_draft_frac: 0.35,
            self_accept_penalty: 0.85,
            selfspec_tiers: String::new(),
        }
    }
}

impl PolicyConfig {
    /// Set one `[policy]` key (already stripped of the section prefix).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let f64_ = |v: &str| -> Result<f64> {
            v.parse().map_err(|_| anyhow!("expected float, got {v:?}"))
        };
        match key {
            "kind" => {
                self.kind = match val {
                    "static" => PolicyKind::Static,
                    "bandit" => PolicyKind::Bandit,
                    "selfspec" | "self-spec" | "self_spec" => PolicyKind::SelfSpec,
                    other => bail!("unknown policy kind {other:?}"),
                }
            }
            "bandit_c" => self.bandit_c = f64_(val)?,
            "forget" => self.forget = f64_(val)?,
            "window" => self.window = f64_(val)?,
            "self_draft_frac" => self.self_draft_frac = f64_(val)?,
            "self_accept_penalty" => self.self_accept_penalty = f64_(val)?,
            "selfspec_tiers" => self.selfspec_tiers = val.to_string(),
            _ => bail!("unknown config key"),
        }
        Ok(())
    }

    /// True for the default bit-inert configuration path.
    pub fn is_static(&self) -> bool {
        self.kind == PolicyKind::Static
    }

    /// Does tier `name` run the skip-layer self-drafting backend swap?
    /// Only `kind = "selfspec"` swaps anything; an empty tier list
    /// means every tier.
    pub fn selfspec_tier(&self, name: &str) -> bool {
        if self.kind != PolicyKind::SelfSpec {
            return false;
        }
        let list = self.selfspec_tiers.trim();
        list.is_empty() || list.split(',').any(|t| t.trim() == name)
    }

    /// Construct the policy object for instance `instance` of a run
    /// seeded `seed`.
    pub fn build(&self, seed: u64, instance: usize) -> Box<dyn DraftPolicy> {
        match self.kind {
            PolicyKind::Static => Box::new(StaticSelector),
            PolicyKind::Bandit => Box::new(BanditPolicy::new(self, seed, instance)),
            PolicyKind::SelfSpec => Box::new(SelfSpecStrategy),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fitted_tsd(c1: f64, c2: f64) -> TsdPredictor {
        let mut t = TsdPredictor::new(1, 1);
        for s in 0..30 {
            for d in 1..30 {
                t.observe(s * 64, d, 0.003 + c1 * (s * 64) as f64 + c2 * d as f64);
            }
        }
        t.refit();
        t
    }

    fn tree(rng: &mut Rng, size: usize) -> CandidateTree {
        let mut t = CandidateTree::new(0);
        for _ in 1..size {
            let parent = rng.below(t.len());
            let o = 0.2 + 0.8 * rng.f32();
            t.add_child(parent, rng.below(64) as i32, o);
        }
        for n in &mut t.nodes {
            n.w = n.dl;
        }
        t
    }

    fn ctx(batch: usize, n_seq: usize, version: u64) -> PolicyCtx {
        PolicyCtx { batch, n_seq, tier: 0, backlog: 0, model_version: version }
    }

    /// Drive `policy` once with a standard argument set; returns the
    /// chosen budget.
    fn drive(policy: &mut dyn DraftPolicy, c: &PolicyCtx, trees: &[&CandidateTree]) -> usize {
        let cfg = SelectorConfig::default();
        let mut tsd = fitted_tsd(1e-7, 5e-5);
        let choice = policy.choose(
            c,
            SelectArgs { cfg: &cfg, tsd: &mut tsd, trees, n_seq: c.n_seq, max_n: 24 },
        );
        choice.n
    }

    #[test]
    fn default_config_is_static_and_builds_static() {
        let cfg = PolicyConfig::default();
        assert!(cfg.is_static());
        let mut p = cfg.build(7, 0);
        assert_eq!(p.name(), "static");
        assert_eq!(p.digest(), 0);
        assert!(p.decision().is_none());
        // Static delegates: same choice as calling the selector directly.
        let mut rng = Rng::new(3);
        let t = tree(&mut rng, 24);
        let refs = [&t];
        let sel_cfg = SelectorConfig::default();
        let mut tsd_a = fitted_tsd(1e-7, 5e-5);
        let mut tsd_b = fitted_tsd(1e-7, 5e-5);
        let c = ctx(1, 256, 0);
        let a = p.choose(
            &c,
            SelectArgs { cfg: &sel_cfg, tsd: &mut tsd_a, trees: &refs, n_seq: 256, max_n: 24 },
        );
        let b = selector::select_strategy(&sel_cfg, &mut tsd_b, &refs, 256, 24);
        assert_eq!(a.n, b.n);
        assert_eq!(a.predicted_al.to_bits(), b.predicted_al.to_bits());
    }

    #[test]
    fn policy_section_parses_and_rejects() {
        let mut cfg = PolicyConfig::default();
        cfg.set("kind", "bandit").unwrap();
        assert_eq!(cfg.kind, PolicyKind::Bandit);
        cfg.set("kind", "selfspec").unwrap();
        assert_eq!(cfg.kind, PolicyKind::SelfSpec);
        cfg.set("kind", "static").unwrap();
        assert!(cfg.is_static());
        cfg.set("bandit_c", "0.9").unwrap();
        assert_eq!(cfg.bandit_c, 0.9);
        cfg.set("forget", "0.5").unwrap();
        cfg.set("window", "64").unwrap();
        cfg.set("self_draft_frac", "0.2").unwrap();
        cfg.set("self_accept_penalty", "0.7").unwrap();
        cfg.set("selfspec_tiers", "l40s, a100").unwrap();
        assert!(cfg.set("kind", "sideways").is_err());
        assert!(cfg.set("nope", "1").is_err());
        assert!(cfg.set("bandit_c", "abc").is_err());
    }

    #[test]
    fn selfspec_tier_filter() {
        let mut cfg = PolicyConfig { kind: PolicyKind::SelfSpec, ..PolicyConfig::default() };
        // Empty list: every tier swaps.
        assert!(cfg.selfspec_tier("l40s"));
        assert!(cfg.selfspec_tier("h100"));
        cfg.selfspec_tiers = "l40s, a100".into();
        assert!(cfg.selfspec_tier("l40s"));
        assert!(cfg.selfspec_tier("a100"));
        assert!(!cfg.selfspec_tier("h100"));
        // Non-selfspec kinds never swap, whatever the list says.
        cfg.kind = PolicyKind::Bandit;
        assert!(!cfg.selfspec_tier("l40s"));
    }

    #[test]
    fn bandit_replays_bit_identically() {
        let cfg = PolicyConfig { kind: PolicyKind::Bandit, ..PolicyConfig::default() };
        let run = || {
            let mut p = BanditPolicy::new(&cfg, 42, 3);
            let mut rng = Rng::new(9);
            let trees: Vec<CandidateTree> = (0..4).map(|_| tree(&mut rng, 24)).collect();
            let refs: Vec<&CandidateTree> = trees.iter().collect();
            let mut ns = Vec::new();
            for step in 0..200u64 {
                let c = ctx(4, 1024, step / 80); // two version bumps
                let n = drive(&mut p, &c, &refs);
                ns.push(n);
                p.feedback(&c, (n.min(6) * 2).max(1), 0.02);
            }
            (ns, p.digest())
        };
        let (ns_a, dig_a) = run();
        let (ns_b, dig_b) = run();
        assert_eq!(ns_a, ns_b);
        assert_eq!(dig_a, dig_b);
        // A different instance id gets an unrelated stream/state.
        let mut other = BanditPolicy::new(&cfg, 42, 4);
        let mut rng = Rng::new(9);
        let t = tree(&mut rng, 24);
        let c = ctx(4, 1024, 0);
        drive(&mut other, &c, &[&t]);
        assert_ne!(other.digest(), dig_a);
    }

    #[test]
    fn bandit_converges_to_better_arm() {
        // Reward n=8 heavily, everything else weakly: after warmup the
        // bandit should pick the n=8 arm most of the time.
        let cfg = PolicyConfig { kind: PolicyKind::Bandit, ..PolicyConfig::default() };
        let mut p = BanditPolicy::new(&cfg, 1, 0);
        let mut rng = Rng::new(5);
        let trees: Vec<CandidateTree> = (0..4).map(|_| tree(&mut rng, 24)).collect();
        let refs: Vec<&CandidateTree> = trees.iter().collect();
        let c = ctx(4, 1024, 0);
        let mut tail_hits = 0usize;
        for step in 0..400 {
            let n = drive(&mut p, &c, &refs);
            let reward = if n == 8 { 400.0 } else { 50.0 };
            p.feedback(&c, reward as usize, 1.0);
            if step >= 300 && n == 8 {
                tail_hits += 1;
            }
        }
        assert!(tail_hits >= 80, "bandit stuck off the best arm: {tail_hits}/100");
    }

    #[test]
    fn forgetting_reopens_exploration_after_barrier() {
        let cfg = PolicyConfig { kind: PolicyKind::Bandit, forget: 0.0, ..PolicyConfig::default() };
        let mut p = BanditPolicy::new(&cfg, 2, 0);
        let mut rng = Rng::new(6);
        let t = tree(&mut rng, 24);
        let refs = [&t];
        let c0 = ctx(1, 256, 0);
        for _ in 0..40 {
            let n = drive(&mut p, &c0, &refs);
            p.feedback(&c0, n, 0.02);
        }
        assert!(p.total > 10.0);
        // Version bump with forget = 0: statistics reset entirely, and
        // the next decision is an exploration pull again.
        let c1 = ctx(1, 256, 1);
        drive(&mut p, &c1, &refs);
        let d = p.decision().expect("bandit records decisions");
        assert!(d.explore, "no re-exploration after barrier: {d:?}");
        assert!(p.total <= 1.0 + 1e-9);
    }

    #[test]
    fn arms_respect_max_n() {
        let cfg = PolicyConfig { kind: PolicyKind::Bandit, ..PolicyConfig::default() };
        let mut p = BanditPolicy::new(&cfg, 3, 0);
        let sel_cfg = SelectorConfig::default();
        let mut rng = Rng::new(7);
        let t = tree(&mut rng, 30);
        let refs = [&t];
        let c = ctx(1, 128, 0);
        for _ in 0..60 {
            let mut tsd = fitted_tsd(1e-7, 5e-5);
            let choice = p.choose(
                &c,
                SelectArgs { cfg: &sel_cfg, tsd: &mut tsd, trees: &refs, n_seq: 128, max_n: 6 },
            );
            assert!(choice.n >= 1 && choice.n <= 6, "budget {} escaped max_n", choice.n);
            p.feedback(&c, choice.n, 0.02);
        }
    }

    #[test]
    fn context_buckets_cover_and_separate() {
        for (batch, n_seq) in [(1, 0), (1, 100_000), (64, 0), (128, 1 << 20), (7, 3000)] {
            let b = context_bucket(&ctx(batch, n_seq, 0));
            assert!(b < N_BUCKETS, "bucket {b} out of range");
        }
        assert_ne!(context_bucket(&ctx(1, 0, 0)), context_bucket(&ctx(64, 0, 0)));
        assert_ne!(context_bucket(&ctx(8, 256, 0)), context_bucket(&ctx(8, 100_000, 0)));
    }
}
