//! Federated cross-shard reallocation (the sharded coordinator's thin
//! top layer).
//!
//! A sharded control plane partitions the fleet across K coordinator
//! shards; each shard runs the full §6.1 policy over its own members
//! and never looks at another shard's instances. What crosses the shard
//! boundary is a fixed-size **load digest** per shard
//! ([`ShardDigest`]): aggregate surplus/deficit against the roofline
//! thresholds plus one designated export and one designated import
//! endpoint. [`plan_federation`] pairs digests greedily — largest net
//! surplus against largest net deficit, the same extreme-pairing scheme
//! [`Reallocator::decide`] uses per instance — and emits at most one
//! cross-shard [`MigrationOrder`] per shard per round (the paper's
//! `m(k) ≤ 1` participation limit, lifted from instances to shards).
//!
//! The orders themselves are ordinary §6.2 migration orders: they ride
//! the existing `Transport` abstraction (cross-shard links are just
//! *worse* links — higher latency, lower bandwidth), so the seqno /
//! limbo / retransmit machinery and the crash reconciliation apply
//! unchanged. No federation state survives between rounds: the digest
//! exchange is stateless, deterministic, and O(K) per round.
//!
//! In-flight orders make a digest's surplus stale for a round or two;
//! that is fine — the migration endpoint's victim pick is the
//! authority, and an over-claimed source refuses the order exactly as
//! it does for intra-shard plans today.
//!
//! [`Reallocator::decide`]: crate::coordinator::reallocator::Reallocator::decide

use std::cmp::Reverse;

use crate::coordinator::reallocator::MigrationOrder;

/// Fixed-size per-shard load summary exchanged on the reallocation
/// cadence. All instance ids are *global* (fleet-wide) ids; thresholds
/// and capacities were already applied by the owning shard when the
/// digest was built, so the planner needs no per-instance knowledge.
#[derive(Clone, Debug, Default)]
pub struct ShardDigest {
    /// The shard this digest describes.
    pub shard: usize,
    /// Σ max(count − threshold, 0) over the shard's live members.
    pub surplus: usize,
    /// Σ min(threshold − count, capacity headroom) over live members
    /// below their threshold.
    pub deficit: usize,
    /// Most-overloaded live member `(global id, its surplus)` — the
    /// shard's designated export endpoint (lowest id on ties).
    pub top_src: Option<(usize, usize)>,
    /// Most-underloaded live member with admission headroom
    /// `(global id, its deficit)` — the designated import endpoint
    /// (lowest id on ties).
    pub top_dst: Option<(usize, usize)>,
    /// The shard's admission-backlog length. A shard with queued
    /// arrivals imports nothing: its deficits will be topped up by
    /// admission, which costs no link bandwidth (the same reasoning
    /// `Reallocator::note_backlog` applies intra-shard).
    pub backlog: usize,
}

impl ShardDigest {
    /// Samples this shard wants to export (0 when balanced/deficient).
    pub fn net_surplus(&self) -> usize {
        self.surplus.saturating_sub(self.deficit)
    }

    /// Samples this shard can absorb (0 when balanced/overloaded, or
    /// while its admission backlog pends).
    pub fn net_deficit(&self) -> usize {
        if self.backlog > 0 {
            0
        } else {
            self.deficit.saturating_sub(self.surplus)
        }
    }
}

/// Pair shard digests into cross-shard migration orders: exporters
/// (net surplus, descending) against importers (net deficit,
/// descending), one order per pair, moving
/// `min(exporter.top_src surplus, importer.top_dst deficit)` samples
/// between the two designated endpoints. Deterministic: ties break on
/// the lower shard id, and the digest slice's order never matters.
pub fn plan_federation(digests: &[ShardDigest]) -> Vec<MigrationOrder> {
    let mut exporters: Vec<&ShardDigest> = digests
        .iter()
        .filter(|d| d.net_surplus() > 0 && d.top_src.is_some())
        .collect();
    let mut importers: Vec<&ShardDigest> = digests
        .iter()
        .filter(|d| d.net_deficit() > 0 && d.top_dst.is_some())
        .collect();
    exporters.sort_by_key(|d| (Reverse(d.net_surplus()), d.shard));
    importers.sort_by_key(|d| (Reverse(d.net_deficit()), d.shard));
    exporters
        .iter()
        .zip(importers.iter())
        .filter_map(|(e, i)| {
            debug_assert_ne!(e.shard, i.shard, "a shard cannot both export and import");
            let (from, s_surplus) = e.top_src?;
            let (to, d_deficit) = i.top_dst?;
            let count = s_surplus.min(d_deficit);
            (count > 0).then_some(MigrationOrder { from, to, count })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn digest(
        shard: usize,
        surplus: usize,
        deficit: usize,
        top_src: Option<(usize, usize)>,
        top_dst: Option<(usize, usize)>,
        backlog: usize,
    ) -> ShardDigest {
        ShardDigest { shard, surplus, deficit, top_src, top_dst, backlog }
    }

    #[test]
    fn balanced_shards_plan_nothing() {
        let d = vec![
            digest(0, 5, 5, Some((0, 5)), Some((1, 5)), 0),
            digest(1, 0, 0, None, None, 0),
        ];
        assert!(plan_federation(&d).is_empty());
    }

    #[test]
    fn extremes_pair_first() {
        // Shard 2 (surplus 20) must pair with shard 0 (deficit 12),
        // shard 3 (surplus 4) with shard 1 (deficit 6).
        let d = vec![
            digest(0, 0, 12, None, Some((1, 7)), 0),
            digest(1, 0, 6, None, Some((9, 3)), 0),
            digest(2, 20, 0, Some((17, 11)), None, 0),
            digest(3, 4, 0, Some((25, 4)), None, 0),
        ];
        let plan = plan_federation(&d);
        assert_eq!(
            plan,
            vec![
                MigrationOrder { from: 17, to: 1, count: 7 },
                MigrationOrder { from: 25, to: 9, count: 3 },
            ]
        );
    }

    #[test]
    fn backlogged_shard_never_imports() {
        let d = vec![
            digest(0, 0, 12, None, Some((1, 7)), 3),
            digest(1, 20, 0, Some((17, 11)), None, 0),
        ];
        assert!(plan_federation(&d).is_empty());
    }

    #[test]
    fn each_shard_participates_at_most_once() {
        // Two exporters, one importer: only the larger exporter fires.
        let d = vec![
            digest(0, 9, 0, Some((2, 6)), None, 0),
            digest(1, 30, 0, Some((8, 14)), None, 0),
            digest(2, 0, 10, None, Some((20, 5)), 0),
        ];
        let plan = plan_federation(&d);
        assert_eq!(plan, vec![MigrationOrder { from: 8, to: 20, count: 5 }]);
    }

    #[test]
    fn plan_is_order_independent() {
        let mut d = vec![
            digest(0, 0, 12, None, Some((1, 7)), 0),
            digest(1, 0, 6, None, Some((9, 3)), 0),
            digest(2, 20, 0, Some((17, 11)), None, 0),
            digest(3, 4, 0, Some((25, 4)), None, 0),
        ];
        let a = plan_federation(&d);
        d.reverse();
        assert_eq!(a, plan_federation(&d));
    }
}
