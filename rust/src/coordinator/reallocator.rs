//! Sample-reallocation policy (paper §6.1).
//!
//! Instance throughput vs sample count follows a roofline (Fig 9): below
//! the *threshold* each extra sample adds near-linear throughput; above it
//! marginal gains vanish. The policy therefore:
//!
//! * classifies instances with `count > threshold` as **sources** and
//!   `count < threshold` as **destinations**;
//! * pairs extremes greedily (largest surplus ↔ largest deficit), moving
//!   `min(s_cur − threshold, threshold − d_cur)` samples per pair;
//! * enforces the Eq-6 constraints: sources never drop below the
//!   threshold, destinations never exceed it, every instance takes part in
//!   at most one migration per decision (`m(k) ≤ 1`);
//! * only runs every `cooldown` steps, and only when inefficiency is
//!   detected (some destination exists while some source has surplus).
//!
//! The threshold comes from offline profiling (Fig 9 knee) and is refined
//! online from (count, throughput) observations.

use crate::utils::stats;

/// One migration order: move `count` samples from `from` to `to`.
#[derive(Clone, Debug, PartialEq)]
pub struct MigrationOrder {
    pub from: usize,
    pub to: usize,
    pub count: usize,
}

#[derive(Clone, Debug)]
pub struct Reallocator {
    pub threshold: usize,
    pub cooldown: u64,
    last_decision: u64,
    /// (sample count, tokens/sec) observations for online refit.
    obs: Vec<(usize, f64)>,
    pub decisions: u64,
    pub refusals: u64,
}

impl Reallocator {
    pub fn new(threshold: usize, cooldown: u64) -> Self {
        Reallocator { threshold: threshold.max(1), cooldown: cooldown.max(1), last_decision: 0, obs: Vec::new(), decisions: 0, refusals: 0 }
    }

    /// Record an instance's (sample count → throughput) operating point.
    pub fn observe(&mut self, sample_count: usize, tokens_per_sec: f64) {
        if sample_count > 0 && tokens_per_sec.is_finite() && tokens_per_sec >= 0.0 {
            self.obs.push((sample_count, tokens_per_sec));
            if self.obs.len() > 100_000 {
                self.obs.drain(..50_000);
            }
        }
    }

    /// A migration was refused (allocation failure on the destination).
    pub fn report_refusal(&mut self) {
        self.refusals += 1;
    }

    /// Re-estimate the roofline knee: the smallest sample count whose
    /// median throughput reaches 60% of the plateau. (The paper's Fig-5
    /// operating points imply a threshold well below the 90% knee — ins.2
    /// is topped up to 6 samples at ~52% of plateau throughput; an
    /// aggressive threshold maximizes drain-phase rebalancing.)
    pub fn refit_threshold(&mut self) {
        if self.obs.len() < 32 {
            return;
        }
        let max_count = self.obs.iter().map(|&(c, _)| c).max().unwrap();
        let mut per_count: Vec<Vec<f64>> = vec![Vec::new(); max_count + 1];
        for &(c, t) in &self.obs {
            per_count[c].push(t);
        }
        let medians: Vec<(usize, f64)> = per_count
            .iter()
            .enumerate()
            .filter(|(_, v)| v.len() >= 3)
            .map(|(c, v)| (c, stats::median(v)))
            .collect();
        if medians.len() < 3 {
            return;
        }
        let plateau = medians
            .iter()
            .map(|&(_, t)| t)
            .fold(f64::NEG_INFINITY, f64::max);
        for &(c, t) in &medians {
            if t >= 0.6 * plateau {
                self.threshold = c.max(1);
                return;
            }
        }
    }

    /// Is a decision due at this step, and is there detectable inefficiency?
    pub fn should_decide(&self, step: u64, counts: &[usize]) -> bool {
        if step < self.last_decision + self.cooldown {
            return false;
        }
        let has_dest = counts.iter().any(|&c| c < self.threshold);
        let has_src = counts.iter().any(|&c| c > self.threshold);
        has_dest && has_src
    }

    /// Greedy pairing under the Eq-6 constraints.
    ///
    /// `counts[i]` = sample count of instance i. `capacity[i]` caps what a
    /// destination may hold (alloc-handshake pre-check).
    pub fn decide(
        &mut self,
        step: u64,
        counts: &[usize],
        capacity: &[usize],
    ) -> Vec<MigrationOrder> {
        self.last_decision = step;
        self.decisions += 1;
        let th = self.threshold;

        // Sort ascending by count (paper: "sort the instances based on the
        // sample count in ascending order … pair largest difference").
        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by_key(|&i| counts[i]);

        let mut dests: Vec<usize> = order.iter().copied().filter(|&i| counts[i] < th).collect();
        let mut srcs: Vec<usize> = order.iter().copied().filter(|&i| counts[i] > th).collect();
        // srcs ascending; we take from the back (largest surplus).
        let mut out = Vec::new();
        while let (Some(&d), Some(&s)) = (dests.first(), srcs.last()) {
            let surplus = counts[s] - th;
            let deficit = (th - counts[d]).min(capacity[d].saturating_sub(counts[d]));
            let k = surplus.min(deficit);
            dests.remove(0);
            srcs.pop();
            if k == 0 {
                continue;
            }
            out.push(MigrationOrder { from: s, to: d, count: k });
        }
        out
    }

    pub fn observations(&self) -> usize {
        self.obs.len()
    }
}

/// Check the Eq-6 constraints for a plan (used by tests and the driver's
/// debug assertions).
pub fn plan_satisfies_constraints(
    counts: &[usize],
    capacity: &[usize],
    threshold: usize,
    plan: &[MigrationOrder],
) -> bool {
    let mut next = counts.to_vec();
    let mut touched = vec![0usize; counts.len()];
    for m in plan {
        if m.from == m.to || m.count == 0 {
            return false;
        }
        touched[m.from] += 1;
        touched[m.to] += 1;
        if next[m.from] < m.count {
            return false;
        }
        next[m.from] -= m.count;
        next[m.to] += m.count;
    }
    // m(k) <= 1
    if touched.iter().any(|&t| t > 1) {
        return false;
    }
    for m in plan {
        // sources stay >= threshold; dests stay <= threshold & <= capacity
        if next[m.from] < threshold {
            return false;
        }
        if next[m.to] > threshold || next[m.to] > capacity[m.to] {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn caps(n: usize) -> Vec<usize> {
        vec![usize::MAX / 2; n]
    }

    #[test]
    fn pairs_extremes_first() {
        let mut r = Reallocator::new(8, 1);
        let counts = [1, 24, 6, 30];
        let plan = r.decide(10, &counts, &caps(4));
        // largest source (30) pairs with smallest dest (1)
        assert_eq!(plan[0], MigrationOrder { from: 3, to: 0, count: 7 });
        assert_eq!(plan[1], MigrationOrder { from: 1, to: 2, count: 2 });
        assert!(plan_satisfies_constraints(&counts, &caps(4), 8, &plan));
    }

    #[test]
    fn paper_fig5_scenario() {
        // ins.1 has 24 samples, ins.2 has 1; threshold 6 → move 5.
        let mut r = Reallocator::new(6, 1);
        let counts = [24, 1];
        let plan = r.decide(1, &counts, &caps(2));
        assert_eq!(plan, vec![MigrationOrder { from: 0, to: 1, count: 5 }]);
        assert!(plan_satisfies_constraints(&counts, &caps(2), 6, &plan));
    }

    #[test]
    fn no_orders_when_balanced() {
        let mut r = Reallocator::new(8, 1);
        assert!(r.decide(1, &[8, 8, 8], &caps(3)).is_empty());
        assert!(!r.should_decide(100, &[8, 8, 8]));
    }

    #[test]
    fn cooldown_gates_decisions() {
        let r = Reallocator::new(4, 10);
        assert!(r.should_decide(10, &[1, 9]));
        let mut r2 = Reallocator::new(4, 10);
        let _ = r2.decide(10, &[1, 9], &caps(2));
        assert!(!r2.should_decide(15, &[1, 9]));
        assert!(r2.should_decide(20, &[1, 9]));
    }

    #[test]
    fn capacity_caps_transfers() {
        let mut r = Reallocator::new(8, 1);
        let counts = [2, 20];
        let cap = [4, 32]; // dest can only hold 2 more
        let plan = r.decide(1, &counts, &cap);
        assert_eq!(plan, vec![MigrationOrder { from: 1, to: 0, count: 2 }]);
        assert!(plan_satisfies_constraints(&counts, &cap, 8, &plan));
    }

    #[test]
    fn property_constraints_always_hold() {
        testutil::check("eq6-constraints", 300, |rng| {
            let n = rng.range(2, 10);
            let th = rng.range(2, 12);
            let counts: Vec<usize> = (0..n).map(|_| rng.below(32)).collect();
            let capacity: Vec<usize> = counts.iter().map(|&c| c + rng.below(32)).collect();
            let mut r = Reallocator::new(th, 1);
            let plan = r.decide(1, &counts, &capacity);
            assert!(
                plan_satisfies_constraints(&counts, &capacity, th, &plan),
                "counts={counts:?} th={th} plan={plan:?}"
            );
        });
    }

    #[test]
    fn property_plan_moves_toward_threshold() {
        // Every order strictly reduces |count - threshold| for both ends.
        testutil::check("moves-toward-threshold", 200, |rng| {
            let n = rng.range(2, 8);
            let th = rng.range(2, 10);
            let counts: Vec<usize> = (0..n).map(|_| rng.below(40)).collect();
            let mut r = Reallocator::new(th, 1);
            let plan = r.decide(1, &counts, &vec![64; n]);
            for m in &plan {
                assert!(counts[m.from] > th);
                assert!(counts[m.to] < th);
                assert!(m.count <= counts[m.from] - th);
                assert!(m.count <= th - counts[m.to]);
            }
        });
    }

    #[test]
    fn threshold_refit_finds_knee() {
        let mut r = Reallocator::new(2, 1);
        // Roofline: throughput = min(c, 10) * 100 (+ noise-free).
        for c in 1..=24 {
            for _ in 0..5 {
                r.observe(c, (c.min(10) * 100) as f64);
            }
        }
        r.refit_threshold();
        // 60%-of-plateau rule: threshold lands at 0.6 * 10 = 6.
        assert!((5..=8).contains(&r.threshold), "{}", r.threshold);
    }

    #[test]
    fn refit_needs_data() {
        let mut r = Reallocator::new(7, 1);
        r.refit_threshold();
        assert_eq!(r.threshold, 7); // unchanged
    }
}
