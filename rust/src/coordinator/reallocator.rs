//! Sample-reallocation policy (paper §6.1).
//!
//! Instance throughput vs sample count follows a roofline (Fig 9): below
//! the *threshold* each extra sample adds near-linear throughput; above it
//! marginal gains vanish. The policy therefore:
//!
//! * classifies instances with `count > threshold` as **sources** and
//!   `count < threshold` as **destinations**;
//! * pairs extremes greedily (largest surplus ↔ largest deficit), moving
//!   `min(s_cur − threshold, threshold − d_cur)` samples per pair;
//! * enforces the Eq-6 constraints: sources never drop below the
//!   threshold, destinations never exceed it, every instance takes part in
//!   at most one migration per decision (`m(k) ≤ 1`);
//! * only runs every `cooldown` steps, and only when inefficiency is
//!   detected (some destination exists while some source has surplus).
//!
//! The threshold comes from offline profiling (Fig 9 knee) and is refined
//! online from (count, throughput) observations.
//!
//! **Heterogeneous fleets.** On a mixed-GPU fleet the roofline knee is a
//! property of the *cost tier*, not of the fleet: an H100 absorbs more
//! concurrent samples than an L40S before its marginal throughput
//! vanishes. [`Reallocator::with_tiers`] therefore keeps one threshold
//! *per tier*, classifies instance `i` against `threshold_of(i)`, and
//! refits each tier's knee only from that tier's (count, throughput)
//! observations ([`Reallocator::observe_on`]). The uniform constructor
//! ([`Reallocator::new`]) is the single-tier special case and behaves
//! exactly as before.
//!
//! **Streaming workloads.** Under continuous batching, occupancy is
//! time-varying: new samples keep arriving while the long tail drains.
//! While a cluster-level admission backlog exists
//! ([`Reallocator::note_backlog`]), instances below their threshold will
//! be topped up by *admission* — which costs nothing — so firing the
//! migration protocol at them would double-fill destinations and waste
//! link bandwidth. The policy therefore reports no inefficiency while a
//! backlog is pending; batch-synchronous callers never report a backlog
//! and behave exactly as before.

use crate::utils::stats;

/// One migration order: move `count` samples from `from` to `to`.
#[derive(Clone, Debug, PartialEq)]
pub struct MigrationOrder {
    /// Source instance id (above its threshold).
    pub from: usize,
    /// Destination instance id (below its threshold).
    pub to: usize,
    /// Samples to move.
    pub count: usize,
}

/// Render a migration plan compactly for trace instants: `"3->5:2,7->1:1"`
/// (one `from->to:count` triple per order, comma-joined; empty plan → `""`).
pub fn plan_summary(plan: &[MigrationOrder]) -> String {
    let mut out = String::new();
    for (k, o) in plan.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!("{}->{}:{}", o.from, o.to, o.count));
    }
    out
}

/// The §6.1 sample-reallocation policy.
#[derive(Clone, Debug)]
pub struct Reallocator {
    /// Uniform knee (tier 0); mirrors `tier_thresholds[0]` after refits.
    pub threshold: usize,
    /// Decision period in scheduler steps.
    pub cooldown: u64,
    last_decision: u64,
    /// Instance → cost-tier index. Empty = every instance is tier 0.
    tier_of: Vec<usize>,
    /// Per-tier roofline knees; `[0]` is the uniform threshold.
    tier_thresholds: Vec<usize>,
    /// Per-tier (sample count, tokens/sec) observations for online refit.
    obs: Vec<Vec<(usize, f64)>>,
    /// Cluster-level admission backlog (streaming runs); while non-zero,
    /// deficits are filled by admission, not migration.
    backlog: usize,
    /// Reallocation decisions taken (for §7.7 SRD accounting).
    pub decisions: u64,
    /// Migration orders that ended in refusal.
    pub refusals: u64,
}

impl Reallocator {
    /// Uniform fleet: one shared threshold for every instance.
    pub fn new(threshold: usize, cooldown: u64) -> Self {
        Reallocator {
            threshold: threshold.max(1),
            cooldown: cooldown.max(1),
            last_decision: 0,
            tier_of: Vec::new(),
            tier_thresholds: vec![threshold.max(1)],
            obs: vec![Vec::new()],
            backlog: 0,
            decisions: 0,
            refusals: 0,
        }
    }

    /// Heterogeneous fleet: `tier_of[i]` maps instance `i` to a cost
    /// tier, `tier_thresholds[t]` is tier `t`'s initial roofline knee
    /// (typically `CostModel::knee`-derived), refined online per tier.
    pub fn with_tiers(tier_thresholds: Vec<usize>, tier_of: Vec<usize>, cooldown: u64) -> Self {
        assert!(!tier_thresholds.is_empty(), "at least one tier required");
        let n_tiers = tier_thresholds.len();
        for &t in &tier_of {
            assert!(t < n_tiers, "tier index {t} out of range ({n_tiers} tiers)");
        }
        let tier_thresholds: Vec<usize> =
            tier_thresholds.into_iter().map(|t| t.max(1)).collect();
        Reallocator {
            threshold: tier_thresholds[0],
            cooldown: cooldown.max(1),
            last_decision: 0,
            tier_of,
            tier_thresholds,
            obs: vec![Vec::new(); n_tiers],
            backlog: 0,
            decisions: 0,
            refusals: 0,
        }
    }

    /// The roofline threshold instance `i` is classified against.
    pub fn threshold_of(&self, i: usize) -> usize {
        match self.tier_of.get(i) {
            Some(&t) => self.tier_thresholds[t],
            None => self.threshold,
        }
    }

    /// Record an instance's (sample count → throughput) operating point
    /// on the default tier (uniform fleets).
    pub fn observe(&mut self, sample_count: usize, tokens_per_sec: f64) {
        self.observe_tier(0, sample_count, tokens_per_sec);
    }

    /// Record an operating point attributed to instance `i`'s cost tier.
    pub fn observe_on(&mut self, instance: usize, sample_count: usize, tokens_per_sec: f64) {
        let tier = self.tier_of.get(instance).copied().unwrap_or(0);
        self.observe_tier(tier, sample_count, tokens_per_sec);
    }

    fn observe_tier(&mut self, tier: usize, sample_count: usize, tokens_per_sec: f64) {
        if sample_count > 0 && tokens_per_sec.is_finite() && tokens_per_sec >= 0.0 {
            let obs = &mut self.obs[tier];
            obs.push((sample_count, tokens_per_sec));
            if obs.len() > 100_000 {
                obs.drain(..50_000);
            }
        }
    }

    /// A migration was refused (allocation failure on the destination).
    pub fn report_refusal(&mut self) {
        self.refusals += 1;
    }

    /// Report the cluster-level admission backlog (streaming workloads).
    /// While non-zero, [`Reallocator::inefficiency`] reports `false`:
    /// pending arrivals will fill under-threshold instances through
    /// ordinary admission, so migrating into them would double-fill the
    /// destinations. Batch-synchronous callers never call this (backlog
    /// stays 0) and are unaffected.
    pub fn note_backlog(&mut self, backlog: usize) {
        self.backlog = backlog;
    }

    /// Re-estimate each tier's roofline knee: the smallest sample count
    /// whose median throughput reaches 60% of that tier's plateau. (The
    /// paper's Fig-5 operating points imply a threshold well below the
    /// 90% knee — ins.2 is topped up to 6 samples at ~52% of plateau
    /// throughput; an aggressive threshold maximizes drain-phase
    /// rebalancing.)
    pub fn refit_threshold(&mut self) {
        for tier in 0..self.tier_thresholds.len() {
            if let Some(th) = Self::fit_knee(&self.obs[tier]) {
                self.tier_thresholds[tier] = th;
                if tier == 0 {
                    self.threshold = th;
                }
            }
        }
    }

    fn fit_knee(obs: &[(usize, f64)]) -> Option<usize> {
        if obs.len() < 32 {
            return None;
        }
        let max_count = obs.iter().map(|&(c, _)| c).max().unwrap();
        let mut per_count: Vec<Vec<f64>> = vec![Vec::new(); max_count + 1];
        for &(c, t) in obs {
            per_count[c].push(t);
        }
        let medians: Vec<(usize, f64)> = per_count
            .iter()
            .enumerate()
            .filter(|(_, v)| v.len() >= 3)
            .map(|(c, v)| (c, stats::median(v)))
            .collect();
        if medians.len() < 3 {
            return None;
        }
        let plateau = medians
            .iter()
            .map(|&(_, t)| t)
            .fold(f64::NEG_INFINITY, f64::max);
        medians
            .iter()
            .find(|&&(_, t)| t >= 0.6 * plateau)
            .map(|&(c, _)| c.max(1))
    }

    /// Is the cooldown over at this step? (Cheap check — callers should
    /// gate on this before gathering per-instance counts.)
    pub fn due(&self, step: u64) -> bool {
        step >= self.last_decision + self.cooldown
    }

    /// First step at which [`Reallocator::due`] will report `true`
    /// again. The parallel engine uses this to size event beats: any run
    /// of steps that stays strictly below this boundary provably never
    /// triggers a cooldown-gated decision, so the per-step `due` checks
    /// inside the beat are no-ops.
    pub fn next_due_step(&self) -> u64 {
        self.last_decision + self.cooldown
    }

    /// Is there detectable inefficiency: some instance below its tier
    /// threshold while another sits above its own? Always `false` while
    /// an admission backlog is pending (see [`Reallocator::note_backlog`]).
    pub fn inefficiency(&self, counts: &[usize]) -> bool {
        if self.backlog > 0 {
            return false;
        }
        let has_dest = counts
            .iter()
            .enumerate()
            .any(|(i, &c)| c < self.threshold_of(i));
        let has_src = counts
            .iter()
            .enumerate()
            .any(|(i, &c)| c > self.threshold_of(i));
        has_dest && has_src
    }

    /// Is a decision due at this step, and is there detectable inefficiency?
    pub fn should_decide(&self, step: u64, counts: &[usize]) -> bool {
        self.due(step) && self.inefficiency(counts)
    }

    /// Greedy pairing under the Eq-6 constraints, against per-tier
    /// thresholds.
    ///
    /// `counts[i]` = sample count of instance i. `capacity[i]` caps what a
    /// destination may hold (alloc-handshake pre-check).
    ///
    /// Candidate selection is the bounded-select formulation
    /// ([`Reallocator::extreme_candidates`]): O(n + m log m) per decision
    /// instead of re-sorting the full occupancy vector, bit-identical to
    /// the historical full sort (pinned by tests against
    /// [`Reallocator::plan_full_sort`]).
    pub fn decide(
        &mut self,
        step: u64,
        counts: &[usize],
        capacity: &[usize],
    ) -> Vec<MigrationOrder> {
        self.last_decision = step;
        self.decisions += 1;
        let (dests, srcs) = self.extreme_candidates(counts);
        self.pair_extremes(counts, capacity, dests, srcs)
    }

    /// Partition instances into destination/source candidate sets,
    /// keeping only the extremes that can participate in one decision,
    /// each sorted ascending by `(count − threshold, index)`.
    ///
    /// The historical formulation stably sorted all n instances by the
    /// signed offset from their own threshold (paper: "sort the
    /// instances based on the sample count in ascending order … pair
    /// largest difference" — with per-tier knees the *difference* is
    /// count − threshold, so a slow tier's heavy overload outranks a
    /// fast tier's higher raw count) and paired from the two ends. That
    /// loop consumes exactly one destination (front) and one source
    /// (back) per iteration, so at most `m = min(|D|, |S|)` of each ever
    /// take part. A stable sort by offset is equivalent to sorting by
    /// `(offset, original index)`; selecting the m smallest destinations
    /// and m largest sources under that composite key (O(n) via
    /// `select_nth_unstable_by_key`) and sorting just those m reproduces
    /// the full sort's prefix and suffix bit-for-bit. At 100k instances
    /// per shardless tick this replaces the O(n log n) sort with
    /// O(n + m log m).
    fn extreme_candidates(&self, counts: &[usize]) -> (Vec<usize>, Vec<usize>) {
        let key = |i: usize| (counts[i] as isize - self.threshold_of(i) as isize, i);
        let mut dests: Vec<usize> = Vec::new();
        let mut srcs: Vec<usize> = Vec::new();
        for i in 0..counts.len() {
            let th = self.threshold_of(i);
            if counts[i] < th {
                dests.push(i);
            } else if counts[i] > th {
                srcs.push(i);
            }
        }
        let m = dests.len().min(srcs.len());
        if m == 0 {
            return (Vec::new(), Vec::new());
        }
        if dests.len() > m {
            dests.select_nth_unstable_by_key(m - 1, |&i| key(i));
            dests.truncate(m);
        }
        if srcs.len() > m {
            let cut = srcs.len() - m;
            srcs.select_nth_unstable_by_key(cut, |&i| key(i));
            srcs.drain(..cut);
        }
        dests.sort_unstable_by_key(|&i| key(i));
        srcs.sort_unstable_by_key(|&i| key(i));
        (dests, srcs)
    }

    /// The greedy extreme-pairing loop shared by [`Reallocator::decide`]
    /// and the [`Reallocator::plan_full_sort`] oracle: one destination
    /// (smallest offset, front) against one source (largest offset,
    /// back) per iteration, `m(k) ≤ 1`.
    fn pair_extremes(
        &self,
        counts: &[usize],
        capacity: &[usize],
        mut dests: Vec<usize>,
        mut srcs: Vec<usize>,
    ) -> Vec<MigrationOrder> {
        let mut out = Vec::new();
        while let (Some(&d), Some(&s)) = (dests.first(), srcs.last()) {
            let surplus = counts[s] - self.threshold_of(s);
            let deficit = (self.threshold_of(d) - counts[d])
                .min(capacity[d].saturating_sub(counts[d]));
            let k = surplus.min(deficit);
            dests.remove(0);
            srcs.pop();
            if k == 0 {
                continue;
            }
            out.push(MigrationOrder { from: s, to: d, count: k });
        }
        out
    }

    /// The original full-sort candidate selection, retained as the
    /// bit-parity oracle for [`Reallocator::decide`]'s bounded select
    /// (tests assert plan equality on random fleets and the golden
    /// presets). Pure: decision counters and the cooldown are untouched.
    #[doc(hidden)]
    pub fn plan_full_sort(&self, counts: &[usize], capacity: &[usize]) -> Vec<MigrationOrder> {
        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by_key(|&i| counts[i] as isize - self.threshold_of(i) as isize);
        let dests: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| counts[i] < self.threshold_of(i))
            .collect();
        let srcs: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| counts[i] > self.threshold_of(i))
            .collect();
        self.pair_extremes(counts, capacity, dests, srcs)
    }

    /// Batched multi-destination pairing: like [`Reallocator::decide`],
    /// but the paper's `m(k) ≤ 1` participation limit is lifted — a
    /// source's full surplus is split across **several** underloaded
    /// destinations, and a destination's full deficit may be served by
    /// several sources. One order per `(from, to)` pair; the whole set
    /// is one decision. Requires the hardened per-order migration
    /// endpoint (concurrent outbound handshakes with disjoint victims).
    ///
    /// Sources are drained largest-surplus-first into
    /// largest-deficit-first destinations, so the skew extremes still
    /// pair up exactly as in the paper's greedy scheme.
    pub fn decide_batched(
        &mut self,
        step: u64,
        counts: &[usize],
        capacity: &[usize],
    ) -> Vec<MigrationOrder> {
        self.last_decision = step;
        self.decisions += 1;

        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by_key(|&i| counts[i] as isize - self.threshold_of(i) as isize);

        // Destinations keep their *remaining* deficit; most-underloaded
        // first (same sort the uniform scheme uses).
        let mut deficits: Vec<(usize, usize)> = order
            .iter()
            .copied()
            .filter(|&i| counts[i] < self.threshold_of(i))
            .map(|i| {
                let d = (self.threshold_of(i) - counts[i])
                    .min(capacity[i].saturating_sub(counts[i]));
                (i, d)
            })
            .collect();
        let srcs: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| counts[i] > self.threshold_of(i))
            .collect();

        let mut out = Vec::new();
        let mut di = 0usize;
        for &s in srcs.iter().rev() {
            let mut surplus = counts[s] - self.threshold_of(s);
            while surplus > 0 && di < deficits.len() {
                let (d, deficit) = &mut deficits[di];
                if *deficit == 0 {
                    di += 1;
                    continue;
                }
                let k = surplus.min(*deficit);
                let to = *d;
                *deficit -= k;
                let filled = *deficit == 0;
                out.push(MigrationOrder { from: s, to, count: k });
                surplus -= k;
                if filled {
                    di += 1;
                }
            }
            if di >= deficits.len() {
                break;
            }
        }
        out
    }

    /// Total (count, throughput) operating points recorded across tiers.
    pub fn observations(&self) -> usize {
        self.obs.iter().map(|o| o.len()).sum()
    }

    /// Crash-recovery placement: distribute `n` requeued samples across
    /// the fleet. Threshold deficits fill first (most-underloaded
    /// instance first — the same ordering [`Reallocator::decide`] uses),
    /// then the remainder spreads least-loaded-first up to each
    /// instance's capacity. Instances with zero capacity (crashed peers)
    /// never receive work. Returns `(instance, count)` assignments whose
    /// sum is `min(n, total headroom)` — the caller backlogs or refuses
    /// whatever could not be placed. Not a §6.1 decision: the cooldown
    /// and decision counters are untouched.
    pub fn plan_requeue(
        &self,
        counts: &[usize],
        capacity: &[usize],
        n: usize,
    ) -> Vec<(usize, usize)> {
        let mut counts = counts.to_vec();
        let mut remaining = n;
        let mut out: Vec<(usize, usize)> = Vec::new();
        // Pass 1: fill roofline deficits, most-underloaded first.
        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by_key(|&i| counts[i] as isize - self.threshold_of(i) as isize);
        for &i in &order {
            if remaining == 0 {
                break;
            }
            let room = self
                .threshold_of(i)
                .saturating_sub(counts[i])
                .min(capacity[i].saturating_sub(counts[i]));
            let k = room.min(remaining);
            if k > 0 {
                out.push((i, k));
                counts[i] += k;
                remaining -= k;
            }
        }
        // Pass 2: spread the rest least-loaded-first up to capacity.
        let mut by_load: Vec<usize> = (0..counts.len()).collect();
        by_load.sort_by_key(|&i| counts[i]);
        for &i in &by_load {
            if remaining == 0 {
                break;
            }
            let k = capacity[i].saturating_sub(counts[i]).min(remaining);
            if k > 0 {
                out.push((i, k));
                counts[i] += k;
                remaining -= k;
            }
        }
        out
    }
}

/// Check the Eq-6 constraints for a plan (used by tests and the driver's
/// debug assertions) against a uniform threshold.
pub fn plan_satisfies_constraints(
    counts: &[usize],
    capacity: &[usize],
    threshold: usize,
    plan: &[MigrationOrder],
) -> bool {
    plan_satisfies_constraints_tiered(counts, capacity, &vec![threshold; counts.len()], plan)
}

/// Constraint check for batched multi-destination plans
/// ([`Reallocator::decide_batched`]): the `m(k) ≤ 1` participation limit
/// is replaced by (a) one order per `(from, to)` pair, (b) no instance
/// acting as both source and destination; sources never drop below their
/// threshold, destinations never exceed theirs (or their capacity).
pub fn plan_satisfies_constraints_batched(
    counts: &[usize],
    capacity: &[usize],
    thresholds: &[usize],
    plan: &[MigrationOrder],
) -> bool {
    let mut next = counts.to_vec();
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(plan.len());
    let mut is_src = vec![false; counts.len()];
    let mut is_dst = vec![false; counts.len()];
    for m in plan {
        if m.from == m.to || m.count == 0 {
            return false;
        }
        if pairs.contains(&(m.from, m.to)) {
            return false; // duplicate (from, to) order in one decision
        }
        pairs.push((m.from, m.to));
        is_src[m.from] = true;
        is_dst[m.to] = true;
        if next[m.from] < m.count {
            return false;
        }
        next[m.from] -= m.count;
        next[m.to] += m.count;
    }
    if is_src.iter().zip(&is_dst).any(|(&s, &d)| s && d) {
        return false; // an instance cannot shed and absorb in one decision
    }
    for m in plan {
        if next[m.from] < thresholds[m.from] {
            return false;
        }
        if next[m.to] > thresholds[m.to] || next[m.to] > capacity[m.to] {
            return false;
        }
    }
    true
}

/// Eq-6 constraint check against per-instance thresholds (mixed fleets).
pub fn plan_satisfies_constraints_tiered(
    counts: &[usize],
    capacity: &[usize],
    thresholds: &[usize],
    plan: &[MigrationOrder],
) -> bool {
    let mut next = counts.to_vec();
    let mut touched = vec![0usize; counts.len()];
    for m in plan {
        if m.from == m.to || m.count == 0 {
            return false;
        }
        touched[m.from] += 1;
        touched[m.to] += 1;
        if next[m.from] < m.count {
            return false;
        }
        next[m.from] -= m.count;
        next[m.to] += m.count;
    }
    // m(k) <= 1
    if touched.iter().any(|&t| t > 1) {
        return false;
    }
    for m in plan {
        // sources stay >= their threshold; dests stay <= theirs & <= capacity
        if next[m.from] < thresholds[m.from] {
            return false;
        }
        if next[m.to] > thresholds[m.to] || next[m.to] > capacity[m.to] {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn caps(n: usize) -> Vec<usize> {
        vec![usize::MAX / 2; n]
    }

    #[test]
    fn pairs_extremes_first() {
        let mut r = Reallocator::new(8, 1);
        let counts = [1, 24, 6, 30];
        let plan = r.decide(10, &counts, &caps(4));
        // largest source (30) pairs with smallest dest (1)
        assert_eq!(plan[0], MigrationOrder { from: 3, to: 0, count: 7 });
        assert_eq!(plan[1], MigrationOrder { from: 1, to: 2, count: 2 });
        assert!(plan_satisfies_constraints(&counts, &caps(4), 8, &plan));
    }

    #[test]
    fn paper_fig5_scenario() {
        // ins.1 has 24 samples, ins.2 has 1; threshold 6 → move 5.
        let mut r = Reallocator::new(6, 1);
        let counts = [24, 1];
        let plan = r.decide(1, &counts, &caps(2));
        assert_eq!(plan, vec![MigrationOrder { from: 0, to: 1, count: 5 }]);
        assert!(plan_satisfies_constraints(&counts, &caps(2), 6, &plan));
    }

    #[test]
    fn no_orders_when_balanced() {
        let mut r = Reallocator::new(8, 1);
        assert!(r.decide(1, &[8, 8, 8], &caps(3)).is_empty());
        assert!(!r.should_decide(100, &[8, 8, 8]));
    }

    #[test]
    fn cooldown_gates_decisions() {
        let r = Reallocator::new(4, 10);
        assert!(r.should_decide(10, &[1, 9]));
        let mut r2 = Reallocator::new(4, 10);
        let _ = r2.decide(10, &[1, 9], &caps(2));
        assert!(!r2.should_decide(15, &[1, 9]));
        assert!(r2.should_decide(20, &[1, 9]));
    }

    #[test]
    fn capacity_caps_transfers() {
        let mut r = Reallocator::new(8, 1);
        let counts = [2, 20];
        let cap = [4, 32]; // dest can only hold 2 more
        let plan = r.decide(1, &counts, &cap);
        assert_eq!(plan, vec![MigrationOrder { from: 1, to: 0, count: 2 }]);
        assert!(plan_satisfies_constraints(&counts, &cap, 8, &plan));
    }

    #[test]
    fn property_constraints_always_hold() {
        testutil::check("eq6-constraints", 300, |rng| {
            let n = rng.range(2, 10);
            let th = rng.range(2, 12);
            let counts: Vec<usize> = (0..n).map(|_| rng.below(32)).collect();
            let capacity: Vec<usize> = counts.iter().map(|&c| c + rng.below(32)).collect();
            let mut r = Reallocator::new(th, 1);
            let plan = r.decide(1, &counts, &capacity);
            assert!(
                plan_satisfies_constraints(&counts, &capacity, th, &plan),
                "counts={counts:?} th={th} plan={plan:?}"
            );
        });
    }

    #[test]
    fn property_plan_moves_toward_threshold() {
        // Every order strictly reduces |count - threshold| for both ends.
        testutil::check("moves-toward-threshold", 200, |rng| {
            let n = rng.range(2, 8);
            let th = rng.range(2, 10);
            let counts: Vec<usize> = (0..n).map(|_| rng.below(40)).collect();
            let mut r = Reallocator::new(th, 1);
            let plan = r.decide(1, &counts, &vec![64; n]);
            for m in &plan {
                assert!(counts[m.from] > th);
                assert!(counts[m.to] < th);
                assert!(m.count <= counts[m.from] - th);
                assert!(m.count <= th - counts[m.to]);
            }
        });
    }

    #[test]
    fn backlog_suppresses_migration_until_drained() {
        // While an admission backlog exists, deficits are filled by
        // arrivals — no migration inefficiency is reported.
        let mut r = Reallocator::new(8, 1);
        let counts = [1, 24];
        assert!(r.should_decide(10, &counts));
        r.note_backlog(5);
        assert!(!r.inefficiency(&counts));
        assert!(!r.should_decide(10, &counts));
        // Backlog drained: the ordinary policy resumes.
        r.note_backlog(0);
        assert!(r.should_decide(10, &counts));
    }

    #[test]
    fn threshold_refit_finds_knee() {
        let mut r = Reallocator::new(2, 1);
        // Roofline: throughput = min(c, 10) * 100 (+ noise-free).
        for c in 1..=24 {
            for _ in 0..5 {
                r.observe(c, (c.min(10) * 100) as f64);
            }
        }
        r.refit_threshold();
        // 60%-of-plateau rule: threshold lands at 0.6 * 10 = 6.
        assert!((5..=8).contains(&r.threshold), "{}", r.threshold);
    }

    #[test]
    fn refit_needs_data() {
        let mut r = Reallocator::new(7, 1);
        r.refit_threshold();
        assert_eq!(r.threshold, 7); // unchanged
    }

    #[test]
    fn tiered_thresholds_classify_per_instance() {
        // Instances 0-1 are a slow tier (knee 6), 2-3 a fast tier
        // (knee 16): a count of 10 is a *source* on the slow tier and a
        // *destination* on the fast tier.
        let mut r = Reallocator::with_tiers(vec![6, 16], vec![0, 0, 1, 1], 1);
        assert_eq!(r.threshold_of(0), 6);
        assert_eq!(r.threshold_of(3), 16);
        let counts = [10, 6, 10, 16];
        assert!(r.should_decide(1, &counts));
        let caps = [64, 64, 64, 64];
        let plan = r.decide(1, &counts, &caps);
        assert_eq!(plan, vec![MigrationOrder { from: 0, to: 2, count: 4 }]);
        assert!(plan_satisfies_constraints_tiered(
            &counts,
            &caps,
            &[6, 6, 16, 16],
            &plan
        ));
    }

    #[test]
    fn tiered_refit_is_per_tier() {
        // Tier 0 plateaus at 5 samples, tier 1 at 20: after refit, the
        // tiers must hold distinct knees.
        let mut r = Reallocator::with_tiers(vec![2, 2], vec![0, 1], 1);
        for c in 1..=24 {
            for _ in 0..5 {
                r.observe_on(0, c, (c.min(5) * 100) as f64);
                r.observe_on(1, c, (c.min(20) * 300) as f64);
            }
        }
        r.refit_threshold();
        assert!((2..=5).contains(&r.threshold_of(0)), "{}", r.threshold_of(0));
        assert!((10..=16).contains(&r.threshold_of(1)), "{}", r.threshold_of(1));
        assert!(r.threshold_of(1) > r.threshold_of(0));
    }

    #[test]
    fn batched_splits_one_source_across_three_destinations() {
        // One heavily loaded source, three starved destinations: the
        // batched planner must emit one order per destination (1 → ≥3),
        // which the single-destination scheme cannot do.
        let mut r = Reallocator::new(8, 1);
        let counts = [32, 2, 3, 4];
        let caps = caps(4);
        let plan = r.decide_batched(1, &counts, &caps);
        assert_eq!(plan.len(), 3, "{plan:?}");
        assert!(plan.iter().all(|m| m.from == 0), "{plan:?}");
        let mut tos: Vec<usize> = plan.iter().map(|m| m.to).collect();
        tos.sort_unstable();
        assert_eq!(tos, vec![1, 2, 3]);
        // Deficits filled exactly: dest k ends at the threshold.
        assert_eq!(
            plan.iter().map(|m| m.count).sum::<usize>(),
            (8 - 2) + (8 - 3) + (8 - 4)
        );
        assert!(plan_satisfies_constraints_batched(&counts, &caps, &[8; 4], &plan));
        // The classic scheme pairs the source with only one destination.
        let mut uni = Reallocator::new(8, 1);
        assert_eq!(uni.decide(1, &counts, &caps).len(), 1);
    }

    #[test]
    fn batched_multiple_sources_fill_one_deep_deficit() {
        // Two mildly overloaded sources, one deep deficit: both sources
        // contribute (lifted m(k) ≤ 1 on the destination side too).
        let mut r = Reallocator::new(10, 1);
        let counts = [13, 12, 1];
        let caps = caps(3);
        let plan = r.decide_batched(1, &counts, &caps);
        assert_eq!(plan.len(), 2, "{plan:?}");
        assert!(plan.iter().all(|m| m.to == 2));
        assert_eq!(plan.iter().map(|m| m.count).sum::<usize>(), 5);
        assert!(plan_satisfies_constraints_batched(&counts, &caps, &[10; 3], &plan));
    }

    #[test]
    fn batched_equals_classic_when_one_pair_suffices() {
        // Single source, single destination: both planners agree.
        let counts = [24, 1];
        let mut a = Reallocator::new(6, 1);
        let mut b = Reallocator::new(6, 1);
        assert_eq!(
            a.decide(1, &counts, &caps(2)),
            b.decide_batched(1, &counts, &caps(2))
        );
    }

    #[test]
    fn property_batched_constraints_always_hold() {
        testutil::check("batched-constraints", 300, |rng| {
            let n = rng.range(2, 12);
            let th = rng.range(2, 12);
            let counts: Vec<usize> = (0..n).map(|_| rng.below(40)).collect();
            let capacity: Vec<usize> = counts.iter().map(|&c| c + rng.below(32)).collect();
            let mut r = Reallocator::new(th, 1);
            let plan = r.decide_batched(1, &counts, &capacity);
            assert!(
                plan_satisfies_constraints_batched(&counts, &capacity, &vec![th; n], &plan),
                "counts={counts:?} th={th} plan={plan:?}"
            );
        });
    }

    #[test]
    fn plan_requeue_fills_deficits_then_spreads() {
        let r = Reallocator::new(8, 1);
        // Instance 1 is 6 below threshold, instance 0 is 2 below.
        let counts = [6, 2, 12];
        let caps = [40, 40, 40];
        let plan = r.plan_requeue(&counts, &caps, 10);
        assert_eq!(plan.iter().map(|&(_, k)| k).sum::<usize>(), 10);
        // Deficits first: instance 1 takes 6, instance 0 takes 2; the
        // remaining 2 spread least-loaded-first (both now at 8 → index
        // order).
        assert_eq!(plan[0], (1, 6));
        assert_eq!(plan[1], (0, 2));
        // No instance ends above its capacity.
        let mut next = counts;
        for &(i, k) in &plan {
            next[i] += k;
        }
        for (i, &c) in next.iter().enumerate() {
            assert!(c <= caps[i], "instance {i} over capacity: {c}");
        }
    }

    #[test]
    fn plan_requeue_skips_zero_capacity_and_caps_total() {
        let r = Reallocator::new(8, 1);
        // Instance 0 crashed (capacity 0); fleet headroom is 5.
        let counts = [0, 3, 7];
        let caps = [0, 4, 11];
        let plan = r.plan_requeue(&counts, &caps, 100);
        assert!(plan.iter().all(|&(i, _)| i != 0), "crashed peer got work: {plan:?}");
        assert_eq!(
            plan.iter().map(|&(_, k)| k).sum::<usize>(),
            (4 - 3) + (11 - 7),
            "placement is bounded by fleet headroom"
        );
        // Nothing to place → empty plan.
        assert!(r.plan_requeue(&counts, &caps, 0).is_empty());
    }

    #[test]
    fn property_plan_requeue_never_overfills() {
        testutil::check("plan-requeue-bounds", 200, |rng| {
            let n = rng.range(1, 10);
            let th = rng.range(1, 12);
            let counts: Vec<usize> = (0..n).map(|_| rng.below(24)).collect();
            // Some instances are "crashed": zero capacity.
            let caps: Vec<usize> = counts
                .iter()
                .map(|&c| if rng.chance(0.25) { 0 } else { c + rng.below(16) })
                .collect();
            let k = rng.below(64);
            let r = Reallocator::new(th, 1);
            let plan = r.plan_requeue(&counts, &caps, k);
            let mut next = counts.clone();
            let mut placed = 0usize;
            for &(i, m) in &plan {
                assert!(m > 0, "empty assignment in {plan:?}");
                next[i] += m;
                placed += m;
            }
            let headroom: usize = counts
                .iter()
                .zip(&caps)
                .map(|(&c, &cap)| cap.saturating_sub(c))
                .sum();
            assert_eq!(placed, k.min(headroom), "counts={counts:?} caps={caps:?} k={k}");
            for (i, &c) in next.iter().enumerate() {
                assert!(
                    caps[i] >= c || counts[i] >= caps[i],
                    "instance {i} overfilled: {c} > {}",
                    caps[i]
                );
            }
        });
    }

    #[test]
    fn property_bounded_select_matches_full_sort() {
        // decide()'s O(n + m log m) extreme selection must reproduce the
        // historical full-sort plan bit-for-bit, including on tiered
        // fleets where the composite (offset, index) key does the
        // stable-sort tie-breaking.
        testutil::check("bounded-select-parity", 400, |rng| {
            let n = rng.range(2, 64);
            let tiers = rng.range(1, 4);
            let ths: Vec<usize> = (0..tiers).map(|_| rng.range(2, 14)).collect();
            let tier_of: Vec<usize> = (0..n).map(|_| rng.below(tiers)).collect();
            let counts: Vec<usize> = (0..n).map(|_| rng.below(24)).collect();
            let capacity: Vec<usize> =
                counts.iter().map(|&c| c + rng.below(24)).collect();
            let mut r = Reallocator::with_tiers(ths, tier_of, 1);
            let oracle = r.plan_full_sort(&counts, &capacity);
            let fast = r.decide(1, &counts, &capacity);
            assert_eq!(oracle, fast, "counts={counts:?}");
        });
    }

    #[test]
    fn bounded_select_matches_full_sort_with_ties() {
        // Many instances share the same offset: the stable sort's
        // original-index tie-break is exactly what the composite key
        // must reproduce.
        let counts = [1, 1, 1, 20, 20, 20, 8, 8];
        let caps = caps(8);
        let mut r = Reallocator::new(8, 1);
        let oracle = r.plan_full_sort(&counts, &caps);
        assert_eq!(oracle, r.decide(1, &counts, &caps));
        assert_eq!(oracle[0], MigrationOrder { from: 5, to: 0, count: 12 });
    }

    #[test]
    fn uniform_is_single_tier_special_case() {
        // new() and with_tiers(single tier) make identical decisions.
        let counts = [1, 24, 6, 30];
        let mut uni = Reallocator::new(8, 1);
        let mut one = Reallocator::with_tiers(vec![8], vec![0, 0, 0, 0], 1);
        assert_eq!(
            uni.decide(10, &counts, &caps(4)),
            one.decide(10, &counts, &caps(4))
        );
    }
}
