//! Multi-instance generation driver (Fig 6 workflow).
//!
//! One worker thread per generation instance (each owns its PJRT client —
//! the "one client per GPU" topology), a monitor loop in the caller's
//! thread, and message-passing for the reallocation/migration protocol:
//!
//! ```text
//!   monitor                worker s                worker d
//!     │  MigrateOut(s→d,k)   │                        │
//!     ├──────────────────────▶ pick victims           │
//!     │        AllocReq      │                        │
//!     ◀──────────────────────┤                        │
//!     ├──── DeliverAllocReq ─────────────────────────▶ capacity check
//!     │        AllocAck      │                        │
//!     ◀───────────────────────────────────────────────┤
//!     ├──── AllocAck(ok) ────▶ send Stage1 (bulk KV)  │
//!     │        Stage1        │   …keeps decoding…     │
//!     ◀──────────────────────┤                        │
//!     ├──── DeliverStage1 ───────────────────────────▶ unpack (phase 3)
//!     │        Stage2        │ (next step boundary)   │
//!     ◀──────────────────────┤ delta + control        │
//!     ├──── DeliverStage2 ───────────────────────────▶ resume samples
//! ```
//!
//! The endpoint state machine (victim picking, handshake sequencing,
//! Stage-1/Stage-2 packing and restore) lives in
//! [`InstanceCore`](crate::coordinator::core::InstanceCore), shared with
//! the virtual-clock simulation cluster — the worker threads here only
//! pump commands/events between the monitor and that endpoint.
//!
//! Initial allocation is sequential round-robin (paper §4: "training
//! samples are first sequentially allocated to the generation instances").
//!
//! Two entry points share the workers: [`GenerationService::run_batch`]
//! (batch-synchronous, the paper's workload) and
//! [`GenerationService::submit`] + [`GenerationService::run_streaming`]
//! (continuous batching: the monitor drains a wall-clock arrival queue
//! between decode-step events, dispatching each task to the least-loaded
//! instance — mirroring the virtual cluster's admission policy — and the
//! report carries per-sample TTFT/TPOT/queueing-delay percentiles).
//!
//! **Fault injection on the relay.** The monitor *is* this plane's
//! link: every §6.2 protocol event crosses the monitor's
//! `relay_protocol_event` pump. A non-perfect
//! `[transport]` section therefore injects faults right there — each
//! relayed message is planned through the same seeded
//! [`FaultyLink`](crate::sim::link::FaultyLink) the virtual cluster
//! uses: an empty plan drops the relay, extra entries duplicate it
//! (extra *delays* are meaningless at in-process channel speeds and are
//! ignored; reordering still arises from worker-thread interleaving).
//! The monitor then runs the same reliability layer as the sim carrier:
//! held per-order message copies, wall-clock retransmit timers, a
//! bounded handshake phase that aborts into `Cmd::AbortOrder`, and an
//! unbounded committed phase that resends Stage-1/Stage-2 until the
//! destination worker's `Stage2Applied` ack — planned on the reverse
//! path — confirms the order and releases the source's limbo. So the
//! hardened endpoint code paths (idempotent apply, limbo-until-confirm,
//! abort-returns-victims) are exercised on real PJRT workers, not just
//! the virtual clock. Instance-*crash* injection (`[crash]`) remains
//! simulation-only: the driver cannot kill and restart its own worker
//! threads, so `GenerationService::start` rejects a non-zero section.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::core::{
    AckOutcome, MigrateStart, Stage1Msg, Stage2Disposition, Stage2Msg,
};
use crate::coordinator::instance::{
    DecodeMode, FinishedSample, GenerationInstance, PjrtBackend, SampleTask,
};
use crate::coordinator::metrics::{InstanceMetrics, LatencySummary, ProtocolCounters};
use crate::coordinator::migration::AllocRequest;
use crate::coordinator::reallocator::Reallocator;
use crate::coordinator::transport::{MsgClass, PerfectTransport, Transport, TransportConfig};
use crate::runtime::{HostTensor, Manifest, ModelStore};
use crate::sim::link::FaultyLink;
use crate::utils::stats::Ema;

// ---------------------------------------------------------------------------
// Protocol messages
// ---------------------------------------------------------------------------

enum Cmd {
    Add(Vec<SampleTask>),
    MigrateOut { to: usize, count: usize, order: u64 },
    AllocAck { order: u64, ok: bool },
    DeliverAllocReq(AllocRequest),
    DeliverStage1(Stage1Msg<PjrtBackend>),
    DeliverStage2(Stage2Msg<PjrtBackend>),
    /// Source-side confirmation of `order`: releases the endpoint's
    /// limbo copy. On the perfect transport the monitor sends this at
    /// Stage-2 relay time (the in-process channels are reliable FIFO, so
    /// relay time is commit time); on a faulty transport only the
    /// destination worker's `Stage2Applied` ack — itself subject to the
    /// fault plan — triggers it.
    ConfirmOrder(u64),
    /// Monitor-side handshake timeout/budget exhaustion on a faulty
    /// transport: abort the outbound order, returning its waiting tasks
    /// to the queue (live victims never left the batch).
    AbortOrder(u64),
    /// Broadcast fresh actor/draft weights (next RLHF iteration).
    UpdateWeights(Vec<HostTensor>, Vec<HostTensor>),
    /// Emit a Done report for the current batch but keep running.
    Report,
    Stop,
}

enum Event {
    Progress {
        instance: usize,
        sample_count: usize,
        throughput: f64,
        finished: usize,
    },
    AllocReq {
        to: usize,
        req: AllocRequest,
    },
    AllocAck {
        to_source: usize,
        order: u64,
        ok: bool,
    },
    Stage1 {
        to: usize,
        pkt: Stage1Msg<PjrtBackend>,
    },
    Stage2 {
        to: usize,
        pkt: Stage2Msg<PjrtBackend>,
    },
    /// Destination worker applied (or deduplicated) `order`'s Stage-2:
    /// the §6.2 confirmation. The monitor relays it as
    /// `Cmd::ConfirmOrder` on faulty transports (after planning it on
    /// the reverse fault path) and ignores it on the perfect one, where
    /// confirmation already happened at relay time.
    Stage2Applied {
        to_source: usize,
        order: u64,
    },
    MigrationRefused,
    Done {
        instance: usize,
        finished: Vec<FinishedSample>,
        metrics: Box<InstanceMetrics>,
        fig7_curve: Vec<(f64, f64, u64)>,
        accept_corr: f64,
        tsd_cache_hits: u64,
        tsd_cache_misses: u64,
    },
    Fatal {
        instance: usize,
        error: String,
    },
}

/// Per-instance summary returned to the caller.
pub struct InstanceReport {
    /// Instance id.
    pub id: usize,
    /// Per-stage timing and counters.
    pub metrics: InstanceMetrics,
    /// The learned Fig-7 acceptance curve rows.
    pub fig7_curve: Vec<(f64, f64, u64)>,
    /// Pearson correlation of the learned acceptance curve.
    pub accept_corr: f64,
    /// `t_sd` bucket-cache hits (§5.2 cache effectiveness).
    pub tsd_cache_hits: u64,
    /// `t_sd` bucket-cache misses.
    pub tsd_cache_misses: u64,
}

/// Whole-run summary.
pub struct GenerationReport {
    /// Completed samples across the fleet.
    pub finished: Vec<FinishedSample>,
    /// Per-instance reports, ordered by instance id.
    pub instances: Vec<InstanceReport>,
    /// Wall seconds from dispatch to the last report.
    pub wall_secs: f64,
    /// Migration orders issued by the monitor.
    pub migrations: u64,
    /// Migration orders that ended in refusal.
    pub migration_refusals: u64,
    /// Reallocation decisions taken.
    pub realloc_decisions: u64,
    /// Seconds the monitor spent inside reallocation decisions (§7.7 SRD).
    pub srd_secs: f64,
    /// Transport-protocol fault/recovery counters (monitor relay
    /// retransmissions, handshake aborts, fault-plan drops/dups) — the
    /// [`ProtocolCounters`] shape shared with the simulation plane's
    /// `ClusterResult`. All-zero on the perfect transport.
    pub protocol: ProtocolCounters,
    /// Total generated tokens across instances.
    pub total_tokens: u64,
    /// Per-sample serving-latency percentiles (queueing delay, TTFT,
    /// TPOT) over samples that carried a submission stamp — i.e. the
    /// streaming [`GenerationService::submit`] path; empty for plain
    /// batch runs.
    pub latency: LatencySummary,
}

impl GenerationReport {
    /// Tokens per wall second (0 when no time elapsed).
    pub fn throughput_tokens(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / self.wall_secs
        }
    }

    /// Finished samples per wall second (0 when no time elapsed).
    pub fn throughput_samples(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.finished.len() as f64 / self.wall_secs
        }
    }
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

struct Worker {
    core: GenerationInstance,
    cmds: Receiver<Cmd>,
    events: Sender<Event>,
    throughput: Ema,
    last_tokens: u64,
}

impl Worker {
    fn run(mut self) {
        loop {
            // Drain commands.
            loop {
                match self.cmds.try_recv() {
                    Ok(Cmd::Stop) => {
                        self.finishup();
                        return;
                    }
                    Ok(cmd) => {
                        if let Err(e) = self.handle(cmd) {
                            let _ = self.events.send(Event::Fatal {
                                instance: self.core.id,
                                error: format!("{e:#}"),
                            });
                            return;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        self.finishup();
                        return;
                    }
                }
            }

            if self.core.is_idle() {
                // Flush any Stage-2 that was waiting on a step boundary
                // (all victims may have finished during the overlap step).
                self.pump_stage2();
                // Nothing to do: block briefly for commands.
                match self.cmds.recv_timeout(Duration::from_millis(5)) {
                    Ok(Cmd::Stop) => {
                        self.finishup();
                        return;
                    }
                    Ok(cmd) => {
                        if let Err(e) = self.handle(cmd) {
                            let _ = self.events.send(Event::Fatal {
                                instance: self.core.id,
                                error: format!("{e:#}"),
                            });
                            return;
                        }
                    }
                    Err(_) => {}
                }
                continue;
            }

            let t0 = Instant::now();
            if let Err(e) = self.core.step() {
                let _ = self.events.send(Event::Fatal {
                    instance: self.core.id,
                    error: format!("{e:#}"),
                });
                return;
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let new_tokens = self.core.metrics.tokens_out - self.last_tokens;
            self.last_tokens = self.core.metrics.tokens_out;
            let tp = self.throughput.update(new_tokens as f64 / dt);

            // Stage 2 of an in-flight outbound migration fires at the step
            // boundary after Stage 1 (the overlapped decode step).
            self.pump_stage2();

            let _ = self.events.send(Event::Progress {
                instance: self.core.id,
                sample_count: self.core.sample_count(),
                throughput: tp,
                finished: self.core.finished.len(),
            });
        }
    }

    /// Emit every pending Stage-2 packet the endpoint has ready —
    /// batched multi-destination order sets can have several handshakes
    /// reach their step boundary at once.
    fn pump_stage2(&mut self) {
        while let Some(pkt) = self.core.poll_stage2() {
            let _ = self.events.send(Event::Stage2 { to: pkt.to, pkt });
        }
    }

    fn handle(&mut self, cmd: Cmd) -> Result<()> {
        match cmd {
            Cmd::Add(tasks) => {
                for t in tasks {
                    self.core.add_task(t);
                }
            }
            Cmd::MigrateOut { to, count, order } => {
                match self.core.begin_migration(to, count, order) {
                    MigrateStart::Refused => {
                        let _ = self.events.send(Event::MigrationRefused);
                    }
                    MigrateStart::QueueOnly(pkt) => {
                        let _ = self.events.send(Event::Stage2 { to: pkt.to, pkt });
                    }
                    MigrateStart::AllocReq(req) => {
                        let _ = self.events.send(Event::AllocReq { to, req });
                    }
                }
            }
            Cmd::AllocAck { order, ok } => match self.core.handle_alloc_ack(order, ok) {
                AckOutcome::NoPending => {}
                AckOutcome::Refused => {
                    let _ = self.events.send(Event::MigrationRefused);
                }
                AckOutcome::Stage1(pkt) => {
                    let _ = self.events.send(Event::Stage1 { to: pkt.to, pkt });
                }
            },
            Cmd::DeliverAllocReq(req) => {
                let ok = self.core.handle_alloc_req(&req);
                let _ = self.events.send(Event::AllocAck {
                    to_source: req.from_instance,
                    order: req.order,
                    ok,
                });
            }
            Cmd::DeliverStage1(pkt) => self.core.handle_stage1(pkt)?,
            Cmd::DeliverStage2(pkt) => {
                let (order, src) = (pkt.order, pkt.from);
                let disp = self.core.handle_stage2(pkt)?;
                // Applied *and* duplicate deliveries re-ack (the previous
                // ack relay may have been the dropped copy); a delta
                // whose Stage-1 bulk has not arrived stays unacked — the
                // monitor's retransmit timer resends both stages.
                if disp != Stage2Disposition::AwaitingStage1 {
                    let _ = self
                        .events
                        .send(Event::Stage2Applied { to_source: src, order });
                }
            }
            Cmd::ConfirmOrder(order) => self.core.confirm_order(order),
            Cmd::AbortOrder(order) => {
                self.core.abort_handshake(order);
            }
            Cmd::UpdateWeights(tw, dw) => {
                self.core.backend.target.set_weights(&tw)?;
                self.core.backend.draft.set_weights(&dw)?;
            }
            Cmd::Report => self.report_batch(),
            Cmd::Stop => unreachable!("handled by caller"),
        }
        Ok(())
    }

    /// Emit a Done event for the finished-so-far batch without stopping.
    fn report_batch(&mut self) {
        let fig7_curve = self.core.accept_pred.curve();
        let accept_corr = self.core.accept_pred.correlation();
        let _ = self.events.send(Event::Done {
            instance: self.core.id,
            finished: std::mem::take(&mut self.core.finished),
            metrics: Box::new(self.core.metrics.clone()),
            fig7_curve,
            accept_corr,
            tsd_cache_hits: self.core.tsd_pred.cache_hits,
            tsd_cache_misses: self.core.tsd_pred.cache_misses,
        });
    }

    fn finishup(mut self) {
        self.report_batch();
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Wall-clock reallocation cadence for the threaded monitor loop — the
/// real-plane port of `ClusterConfig::realloc_period_secs`. With a
/// period set (`realloc.period_secs > 0`), decisions fire on elapsed
/// virtual-wall-time ticks instead of the step-counter cadence, which is
/// the meaningful schedule once instances step at different rates.
struct ReallocTicker {
    period: Option<f64>,
    next_at: f64,
}

impl ReallocTicker {
    /// A non-positive (or NaN) period disables the timed cadence — the
    /// step-counter cadence stays in charge.
    fn new(period_secs: f64) -> Self {
        let period = (period_secs > 0.0).then_some(period_secs);
        ReallocTicker { period, next_at: period.unwrap_or(0.0) }
    }

    /// True when the timed cadence (rather than the step cadence)
    /// governs decision scheduling.
    fn timed(&self) -> bool {
        self.period.is_some()
    }

    /// One decision tick is due at `now` (seconds since run start)?
    /// Fires at most once per call; a monitor that slept through several
    /// periods (one long decode step) gets a single catch-up tick, and
    /// the schedule stays anchored to the period grid (no drift).
    fn due(&mut self, now: f64) -> bool {
        let Some(p) = self.period else { return false };
        if now < self.next_at {
            return false;
        }
        while self.next_at <= now {
            self.next_at += p;
        }
        true
    }
}

/// Monitor-side carrier state of one in-flight migration order on a
/// faulty `[transport]` — the wall-clock mirror of the sim carrier's
/// order state: held message copies feed the retransmit timer, and the
/// handshake bookkeeping feeds the abort deadline. Never created on the
/// perfect transport.
struct HeldOrder {
    from: usize,
    to: usize,
    /// The destination's affirmative allocation reply was relayed: stop
    /// resending the request and wait for the worker's Stage-1/Stage-2
    /// events (they arrive at its next step boundary).
    acked: bool,
    /// Stage-2 relayed: the order can no longer abort (the victims sit
    /// in the source's limbo); resend until the `Stage2Applied` ack.
    committed: bool,
    /// Handshake retransmissions used (bounded by
    /// [`TransportConfig::retransmit_budget`]).
    resends: usize,
    /// First AllocReq relay instant — anchor of the
    /// [`TransportConfig::handshake_timeout_secs`] deadline.
    started: Instant,
    /// Last (re)send instant — anchor of the retransmit timer.
    last_send: Instant,
    /// Held handshake request (handshake resends).
    req: Option<AllocRequest>,
    /// Held Stage-1 bulk copy (committed resends; the worker dedups).
    stage1: Option<Stage1Msg<PjrtBackend>>,
    /// Held Stage-2 copy (committed resends; the worker dedups).
    stage2: Option<Stage2Msg<PjrtBackend>>,
    /// Committed-phase resend interval, doubled after every resend (up
    /// to [`COMMITTED_BACKOFF_CAP_SECS`]). The channels themselves are
    /// reliable — the usual reason an ack is missing is a *busy* worker
    /// (a first decode step can compile for minutes), and resending the
    /// full KV bulk every base period would flood its queue with
    /// duplicate applies. Loss recovery stays unbounded, just sparser.
    backoff_secs: f64,
}

/// Ceiling of the committed-phase resend backoff: after a long worker
/// stall the order still settles within a second of the worker waking.
const COMMITTED_BACKOFF_CAP_SECS: f64 = 1.0;

/// Assemble the final [`GenerationReport`] from the monitor accumulators
/// (shared by `run_batch` and `run_streaming`).
#[allow(clippy::too_many_arguments)]
fn assemble_report(
    all_finished: Vec<FinishedSample>,
    done_reports: BTreeMap<usize, InstanceReport>,
    wall_secs: f64,
    migrations: u64,
    migration_refusals: u64,
    realloc_decisions: u64,
    srd_secs: f64,
    retransmits: u64,
    handshake_aborts: u64,
    link_faults: (u64, u64),
) -> GenerationReport {
    let total_tokens = done_reports.values().map(|r| r.metrics.tokens_out).sum();
    let latencies: Vec<_> = all_finished.iter().filter_map(|f| f.latency).collect();
    GenerationReport {
        finished: all_finished,
        instances: done_reports.into_values().collect(),
        wall_secs,
        migrations,
        migration_refusals,
        realloc_decisions,
        srd_secs,
        protocol: ProtocolCounters {
            retransmits,
            handshake_aborts,
            link_drops: link_faults.0,
            link_dups: link_faults.1,
        },
        total_tokens,
        latency: LatencySummary::from_samples(&latencies),
    }
}

/// Persistent multi-instance generation service.
///
/// Worker threads (each with its own PJRT client and compiled executables)
/// live across RLHF iterations: [`GenerationService::run_batch`] processes
/// one generation stage, [`GenerationService::update_weights`] broadcasts
/// the freshly trained actor/draft weights, and compiled artifacts are
/// reused — exactly how a serving fleet amortizes warmup.
pub struct GenerationService {
    cfg: RunConfig,
    manifest: Manifest,
    cmd_txs: Vec<Sender<Cmd>>,
    ev_rx: Receiver<Event>,
    joins: Vec<std::thread::JoinHandle<()>>,
    realloc: Reallocator,
    mode: DecodeMode,
    /// Streaming arrival queue: (offset seconds from `run_streaming`
    /// start, task), fed by [`GenerationService::submit`].
    arrival_queue: Vec<(f64, SampleTask)>,
    /// Next cluster-unique migration-order sequence number. Monotone
    /// across batches, so a stale Stage-2 from a previous batch can
    /// never collide with a live order's dedup key.
    next_order: u64,
    /// The §6.2 relay fault plan: [`PerfectTransport`] when the
    /// `[transport]` section is fault-free (zero-overhead relays, PR-4
    /// behavior), else a seeded [`FaultyLink`] shared with the sim plane.
    /// `+ Send` keeps the service itself movable across threads, as it
    /// was before the fault port.
    link: Box<dyn Transport + Send>,
    /// Cached `!link.is_perfect()`: engages the monitor's reliability
    /// layer (held orders, retransmit pump, handshake aborts).
    faulty: bool,
    /// In-flight orders on the faulty relay, keyed by order id.
    held: BTreeMap<u64, HeldOrder>,
    /// Relay retransmissions performed this batch.
    retransmits: u64,
    /// Orders aborted by the monitor's handshake timeout this batch.
    handshake_aborts: u64,
}

impl GenerationService {
    /// Spawn workers. `weights` cross the thread boundary as host tensors
    /// (`xla::Literal` is not Send); each worker materializes its stores.
    pub fn start(
        artifacts_dir: &std::path::Path,
        cfg: &RunConfig,
        mode: DecodeMode,
        target_weights: &[HostTensor],
        draft_weights: &[HostTensor],
    ) -> Result<GenerationService> {
        // The monitor relay honors the `[transport]` fault model (see
        // the module docs) — but whole-instance crash injection cannot
        // be: the driver owns its worker threads and killing one would
        // tear down the process state a real crash destroys for free.
        // Reject a non-zero `[crash]` section loudly rather than
        // silently ignoring it (the simulated plane honors it via
        // `ClusterConfig::crash`).
        if !cfg.crash.is_off() {
            return Err(anyhow!(
                "[crash] instance-crash injection is set, but the threaded driver \
                 cannot kill and restart its own worker threads; use the simulation \
                 plane (ClusterConfig::crash) for crash schedules"
            ));
        }
        let link: Box<dyn Transport + Send> = if cfg.transport.is_perfect() {
            Box::new(PerfectTransport)
        } else {
            Box::new(FaultyLink::new(cfg.transport.clone(), cfg.seed))
        };
        let faulty = !link.is_perfect();
        let n_inst = cfg.rlhf.instances.max(1);
        let manifest = Manifest::load(artifacts_dir)?;
        let (ev_tx, ev_rx) = channel::<Event>();
        let mut cmd_txs: Vec<Sender<Cmd>> = Vec::new();
        let mut joins = Vec::new();

        for i in 0..n_inst {
            let (tx, rx) = channel::<Cmd>();
            cmd_txs.push(tx);
            let ev = ev_tx.clone();
            let man = manifest.clone();
            let cfgc = cfg.clone();
            let tw: Vec<HostTensor> = target_weights.to_vec();
            let dw: Vec<HostTensor> = draft_weights.to_vec();
            let seed = cfg.seed ^ (0xABCD + i as u64);
            joins.push(std::thread::spawn(move || {
                let man = Rc::new(man);
                let mut target = match ModelStore::init(&man, "target", 0) {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = ev.send(Event::Fatal { instance: i, error: format!("{e:#}") });
                        return;
                    }
                };
                let mut draft = ModelStore::init(&man, "draft", 0).unwrap();
                if target.set_weights(&tw).is_err() || draft.set_weights(&dw).is_err() {
                    let _ = ev.send(Event::Fatal {
                        instance: i,
                        error: "weight broadcast failed".into(),
                    });
                    return;
                }
                let inst =
                    match GenerationInstance::new(i, man, target, draft, cfgc, mode, seed) {
                        Ok(x) => x,
                        Err(e) => {
                            let _ = ev
                                .send(Event::Fatal { instance: i, error: format!("{e:#}") });
                            return;
                        }
                    };
                Worker {
                    core: inst,
                    cmds: rx,
                    events: ev,
                    throughput: Ema::new(0.3),
                    last_tokens: 0,
                }
                .run();
            }));
        }
        Ok(GenerationService {
            cfg: cfg.clone(),
            manifest,
            cmd_txs,
            ev_rx,
            joins,
            realloc: Reallocator::new(cfg.realloc.threshold, cfg.realloc.cooldown as u64),
            mode,
            arrival_queue: Vec::new(),
            next_order: 1,
            link,
            faulty,
            held: BTreeMap::new(),
            retransmits: 0,
            handshake_aborts: 0,
        })
    }

    /// The decode mode every worker runs.
    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    /// Broadcast fresh actor/draft weights to every worker.
    pub fn update_weights(
        &self,
        target_weights: &[HostTensor],
        draft_weights: &[HostTensor],
    ) -> Result<()> {
        for tx in &self.cmd_txs {
            tx.send(Cmd::UpdateWeights(
                target_weights.to_vec(),
                draft_weights.to_vec(),
            ))
            .map_err(|_| anyhow!("worker channel closed"))?;
        }
        Ok(())
    }

    /// Fold a worker's terminal event into the monitor's accumulators:
    /// `Done` collects the finished samples + per-instance report (true
    /// once every instance reported), `Fatal` aborts. Shared by
    /// `run_batch` and `run_streaming` — with
    /// [`Self::relay_protocol_event`] this keeps the two monitor loops'
    /// shared logic in one place.
    fn absorb_done(
        ev: Event,
        all_finished: &mut Vec<FinishedSample>,
        done_reports: &mut BTreeMap<usize, InstanceReport>,
        n_inst: usize,
    ) -> Result<bool> {
        match ev {
            Event::Done {
                instance,
                finished,
                metrics,
                fig7_curve,
                accept_corr,
                tsd_cache_hits,
                tsd_cache_misses,
            } => {
                all_finished.extend(finished);
                done_reports.insert(
                    instance,
                    InstanceReport {
                        id: instance,
                        metrics: *metrics,
                        fig7_curve,
                        accept_corr,
                        tsd_cache_hits,
                        tsd_cache_misses,
                    },
                );
                Ok(done_reports.len() == n_inst)
            }
            Event::Fatal { instance, error } => {
                Err(anyhow!("instance {instance} failed: {error}"))
            }
            _ => unreachable!("only terminal events reach absorb_done"),
        }
    }

    /// Relay a pure §6.2 protocol event between workers (AllocReq/Ack,
    /// Stage 1/2, confirmation, refusal accounting). Returns the event
    /// back when it is not a relay (Progress/Done/Fatal) so the calling
    /// monitor loop can apply its own bookkeeping — `run_batch` and
    /// `run_streaming` share this pump so a protocol change cannot
    /// diverge between them.
    ///
    /// On a faulty `[transport]` every relay is planned through the
    /// seeded link first: an empty plan drops it (the retransmit pump
    /// recovers), extra entries duplicate it (the endpoints dedup).
    fn relay_protocol_event(&mut self, ev: Event, refusals: &mut u64) -> Option<Event> {
        match ev {
            Event::AllocReq { to, req } => {
                if self.faulty {
                    let (order, from) = (req.order, req.from_instance);
                    let copies = self.link.plan(MsgClass::AllocReq, from, to).len();
                    let now = Instant::now();
                    let backoff_secs = self.retransmit_period();
                    self.held.insert(
                        order,
                        HeldOrder {
                            from,
                            to,
                            acked: false,
                            committed: false,
                            resends: 0,
                            started: now,
                            last_send: now,
                            req: Some(req.clone()),
                            stage1: None,
                            stage2: None,
                            backoff_secs,
                        },
                    );
                    for _ in 0..copies {
                        let _ = self.cmd_txs[to].send(Cmd::DeliverAllocReq(req.clone()));
                    }
                } else {
                    let _ = self.cmd_txs[to].send(Cmd::DeliverAllocReq(req));
                }
                None
            }
            Event::AllocAck { to_source, order, ok } => {
                if self.faulty {
                    // Carrier dedup: only an unanswered handshake
                    // consumes a reply (retransmitted requests re-ack).
                    let from_dest = match self.held.get(&order) {
                        Some(st) if !st.acked && !st.committed => st.to,
                        _ => return None,
                    };
                    if self.link.plan(MsgClass::AllocAck, from_dest, to_source).is_empty() {
                        return None; // ack lost: the request resend re-acks
                    }
                    if ok {
                        if let Some(st) = self.held.get_mut(&order) {
                            st.acked = true;
                        }
                    } else {
                        self.held.remove(&order);
                    }
                    let _ = self.cmd_txs[to_source].send(Cmd::AllocAck { order, ok });
                } else {
                    let _ = self.cmd_txs[to_source].send(Cmd::AllocAck { order, ok });
                }
                None
            }
            Event::Stage1 { to, pkt } => {
                if self.faulty {
                    let (order, from) = (pkt.order, pkt.from);
                    let copies = self.link.plan(MsgClass::Stage1, from, to).len();
                    if let Some(st) = self.held.get_mut(&order) {
                        st.stage1 = Some(pkt.clone());
                    }
                    for _ in 0..copies {
                        let _ = self.cmd_txs[to].send(Cmd::DeliverStage1(pkt.clone()));
                    }
                } else {
                    let _ = self.cmd_txs[to].send(Cmd::DeliverStage1(pkt));
                }
                None
            }
            Event::Stage2 { to, pkt } => {
                let (src, order) = (pkt.from, pkt.order);
                if self.faulty {
                    // The order commits here: hold the packet for
                    // retransmission and wait for the destination
                    // worker's Stage2Applied ack before confirming.
                    let copies = self.link.plan(MsgClass::Stage2, src, to).len();
                    let now = Instant::now();
                    let backoff_secs = self.retransmit_period();
                    match self.held.get_mut(&order) {
                        Some(st) => {
                            st.acked = true;
                            st.committed = true;
                            st.last_send = now;
                            st.backoff_secs = backoff_secs;
                            st.stage2 = Some(pkt.clone());
                        }
                        None => {
                            // Queue-only order: no handshake preceded it
                            // — the packet itself opens the order,
                            // already committed.
                            self.held.insert(
                                order,
                                HeldOrder {
                                    from: src,
                                    to,
                                    acked: true,
                                    committed: true,
                                    resends: 0,
                                    started: now,
                                    last_send: now,
                                    req: None,
                                    stage1: None,
                                    stage2: Some(pkt.clone()),
                                    backoff_secs,
                                },
                            );
                        }
                    }
                    for _ in 0..copies {
                        let _ = self.cmd_txs[to].send(Cmd::DeliverStage2(pkt.clone()));
                    }
                } else {
                    let _ = self.cmd_txs[to].send(Cmd::DeliverStage2(pkt));
                    // In-process channels are reliable FIFO: once the
                    // Stage-2 is relayed it *will* apply, so the source
                    // can release its limbo copy now.
                    let _ = self.cmd_txs[src].send(Cmd::ConfirmOrder(order));
                }
                None
            }
            Event::Stage2Applied { to_source, order } => {
                if self.faulty {
                    let from_dest = match self.held.get(&order) {
                        Some(st) => st.to,
                        None => return None, // already confirmed
                    };
                    if self.link.plan(MsgClass::AllocAck, from_dest, to_source).is_empty() {
                        // Ack lost: the committed retransmit re-applies
                        // at the worker (Duplicate) and re-acks.
                        return None;
                    }
                    self.held.remove(&order);
                    let _ = self.cmd_txs[to_source].send(Cmd::ConfirmOrder(order));
                }
                // Perfect path: confirmation happened at relay time.
                None
            }
            Event::MigrationRefused => {
                *refusals += 1;
                self.realloc.report_refusal();
                None
            }
            other => Some(other),
        }
    }

    /// Effective retransmit period on the wall clock: the configured
    /// `[transport]` timer, floored at 1 ms so a zero/NaN config cannot
    /// busy-spin the monitor.
    fn retransmit_period(&self) -> f64 {
        let p = self.cfg.transport.retransmit_secs;
        if p.is_finite() && p > 0.0 {
            p.max(1e-3)
        } else {
            TransportConfig::default().retransmit_secs
        }
    }

    /// The batch completed: every expected sample finished somewhere, so
    /// a still-held *committed* order's Stage-2 must have applied (its
    /// victims could not have finished otherwise) — only the
    /// confirmation ack was lost in the fault plan. Settle it so the
    /// source worker releases its limbo copy instead of leaking held KV
    /// across batches; a dangling handshake (nothing shipped — its
    /// reserved tasks would have kept the batch from completing) is
    /// aborted. No-op on the perfect transport.
    fn settle_held_orders(&mut self) {
        let orders: Vec<u64> = self.held.keys().copied().collect();
        for order in orders {
            let st = self.held.remove(&order).expect("collected above");
            if st.committed {
                let _ = self.cmd_txs[st.from].send(Cmd::ConfirmOrder(order));
            } else {
                let _ = self.cmd_txs[st.from].send(Cmd::AbortOrder(order));
            }
        }
    }

    /// Drive the faulty relay's reliability layer: resend held copies
    /// whose timer elapsed; abort handshakes past the retransmit budget
    /// or the hard timeout (`Cmd::AbortOrder` returns the waiting tasks
    /// at the source). Committed orders resend unbounded — their victims
    /// sit in the source's limbo until the destination's ack. No-op on
    /// the perfect transport.
    fn pump_retransmits(&mut self) {
        if !self.faulty {
            return;
        }
        let period = self.retransmit_period();
        let budget = self.cfg.transport.retransmit_budget;
        let deadline = self.cfg.transport.handshake_timeout_secs;
        let now = Instant::now();
        let due: Vec<u64> = self
            .held
            .iter()
            .filter(|(_, st)| {
                // Committed orders back off; the handshake phase keeps
                // the fixed base period (it is bounded anyway).
                let eff = if st.committed { st.backoff_secs } else { period };
                now.duration_since(st.last_send).as_secs_f64() >= eff
            })
            .map(|(&o, _)| o)
            .collect();
        for order in due {
            enum Act {
                Wait,
                Abort(usize),
                Handshake(usize, AllocRequest),
                Committed(usize, Option<Stage1Msg<PjrtBackend>>, Stage2Msg<PjrtBackend>),
            }
            let act = {
                let st = self.held.get_mut(&order).expect("collected above");
                st.last_send = now;
                if st.committed {
                    // Never below the configured base period, even when
                    // that period exceeds the backoff ceiling.
                    st.backoff_secs =
                        (st.backoff_secs * 2.0).min(COMMITTED_BACKOFF_CAP_SECS.max(period));
                    let pkt = st.stage2.clone().expect("committed orders hold Stage-2");
                    Act::Committed(st.to, st.stage1.clone(), pkt)
                } else if st.acked {
                    // Waiting on the source worker's step boundary —
                    // nothing for the carrier to resend.
                    Act::Wait
                } else if now.duration_since(st.started).as_secs_f64() >= deadline
                    || st.resends >= budget
                {
                    Act::Abort(st.from)
                } else {
                    st.resends += 1;
                    let req = st.req.clone().expect("handshake orders hold their request");
                    Act::Handshake(st.to, req)
                }
            };
            match act {
                Act::Wait => {}
                Act::Abort(from) => {
                    self.held.remove(&order);
                    self.handshake_aborts += 1;
                    let _ = self.cmd_txs[from].send(Cmd::AbortOrder(order));
                }
                Act::Handshake(to, req) => {
                    self.retransmits += 1;
                    let copies = self.link.plan(MsgClass::AllocReq, req.from_instance, to);
                    for _ in 0..copies.len() {
                        let _ = self.cmd_txs[to].send(Cmd::DeliverAllocReq(req.clone()));
                    }
                }
                Act::Committed(to, stage1, stage2) => {
                    self.retransmits += 1;
                    let from = stage2.from;
                    if let Some(pkt) = stage1 {
                        for _ in 0..self.link.plan(MsgClass::Stage1, from, to).len() {
                            let _ = self.cmd_txs[to].send(Cmd::DeliverStage1(pkt.clone()));
                        }
                    }
                    for _ in 0..self.link.plan(MsgClass::Stage2, from, to).len() {
                        let _ = self.cmd_txs[to].send(Cmd::DeliverStage2(stage2.clone()));
                    }
                }
            }
        }
    }

    /// Plan one reallocation decision (classic pairing, or the batched
    /// multi-destination order set under `realloc.multi_dest`) and
    /// dispatch each order to its source worker with a fresh
    /// cluster-unique order id. The workers' hardened endpoints run the
    /// handshakes concurrently — one batched set opens several at once.
    /// Returns the number of orders issued.
    fn dispatch_plan(&mut self, step: u64, counts: &[usize], caps: &[usize]) -> u64 {
        let plan = if self.cfg.realloc.multi_dest {
            self.realloc.decide_batched(step, counts, caps)
        } else {
            self.realloc.decide(step, counts, caps)
        };
        let mut issued = 0;
        for m in plan {
            let order = self.next_order;
            self.next_order += 1;
            issued += 1;
            let _ = self.cmd_txs[m.from].send(Cmd::MigrateOut {
                to: m.to,
                count: m.count,
                order,
            });
        }
        issued
    }

    /// Process one batch of samples to completion (one generation stage).
    pub fn run_batch(&mut self, tasks: Vec<SampleTask>) -> Result<GenerationReport> {
        let n_inst = self.cmd_txs.len();
        let expected = tasks.len();
        // Batch-synchronous: no admission backlog can gate reallocation
        // (clears any stale gate from an aborted streaming run).
        self.realloc.note_backlog(0);
        // Drain stale events from a previous batch; reset the faulty
        // relay's per-batch state (order ids stay monotone, so nothing
        // stale can collide).
        while self.ev_rx.try_recv().is_ok() {}
        self.held.clear();
        self.retransmits = 0;
        self.handshake_aborts = 0;
        let faults_at_start = self.link.stats();

        // Sequential initial allocation (§4).
        let mut batches: Vec<Vec<SampleTask>> = vec![Vec::new(); n_inst];
        for (i, t) in tasks.into_iter().enumerate() {
            batches[i % n_inst].push(t);
        }
        for (i, b) in batches.into_iter().enumerate() {
            let _ = self.cmd_txs[i].send(Cmd::Add(b));
        }

        let t0 = Instant::now();
        let mut counts = vec![0usize; n_inst];
        let mut finished_counts = vec![0usize; n_inst];
        let mut step: u64 = 0;
        let mut migrations = 0u64;
        let mut srd_secs = 0.0f64;
        let mut reported = false;
        let mut done_reports: BTreeMap<usize, InstanceReport> = BTreeMap::new();
        let mut all_finished: Vec<FinishedSample> = Vec::new();
        let mut refusals = 0u64;
        let mut ticker = ReallocTicker::new(self.cfg.realloc.period_secs);

        // Generous stall timeout: a worker's FIRST step lazily compiles
        // several XLA executables, which can take minutes on a small
        // shared-CPU box. On a faulty relay the monitor wakes on the
        // retransmit period instead, tracking the stall separately.
        let stall = Duration::from_secs(900);
        let mut last_event = Instant::now();
        loop {
            self.pump_retransmits();
            let timeout = if self.faulty {
                Duration::from_secs_f64(self.retransmit_period())
            } else {
                stall
            };
            let ev = match self.ev_rx.recv_timeout(timeout) {
                Ok(e) => e,
                Err(_) => {
                    if last_event.elapsed() >= stall {
                        return Err(anyhow!(
                            "generation stalled: {} / {expected} finished after {:?}",
                            finished_counts.iter().sum::<usize>(),
                            t0.elapsed()
                        ));
                    }
                    continue;
                }
            };
            last_event = Instant::now();
            let Some(ev) = self.relay_protocol_event(ev, &mut refusals) else {
                continue;
            };
            match ev {
                Event::Progress {
                    instance,
                    sample_count,
                    throughput,
                    finished,
                } => {
                    counts[instance] = sample_count;
                    finished_counts[instance] = finished;
                    step += 1;
                    self.realloc.observe(sample_count.max(1), throughput);

                    // Timed cadence (realloc.period_secs) fires on the
                    // wall clock; otherwise the step-counter cadence.
                    let due = if ticker.timed() {
                        ticker.due(t0.elapsed().as_secs_f64())
                            && self.realloc.inefficiency(&counts)
                    } else {
                        self.realloc.should_decide(step, &counts)
                    };
                    if self.cfg.realloc.enabled && !reported && due {
                        let sw = Instant::now();
                        self.realloc.refit_threshold();
                        let caps: Vec<usize> = vec![
                            self.manifest
                                .batch_buckets
                                .iter()
                                .max()
                                .copied()
                                .unwrap_or(1)
                                * 4;
                            n_inst
                        ];
                        migrations += self.dispatch_plan(step, &counts, &caps);
                        srd_secs += sw.elapsed().as_secs_f64();
                    }

                    if !reported && finished_counts.iter().sum::<usize>() >= expected {
                        reported = true;
                        for tx in &self.cmd_txs {
                            let _ = tx.send(Cmd::Report);
                        }
                    }
                }
                other => {
                    if Self::absorb_done(other, &mut all_finished, &mut done_reports, n_inst)? {
                        break;
                    }
                }
            }
        }

        self.settle_held_orders();
        let faults = self.link.stats();
        Ok(assemble_report(
            all_finished,
            done_reports,
            t0.elapsed().as_secs_f64(),
            migrations,
            refusals,
            self.realloc.decisions,
            srd_secs,
            self.retransmits,
            self.handshake_aborts,
            (faults.0 - faults_at_start.0, faults.1 - faults_at_start.1),
        ))
    }

    /// Queue tasks for the streaming path: they will be dispatched
    /// `offset_secs` after [`GenerationService::run_streaming`] starts
    /// (0 = immediately). Each task's `submitted_at` stamp is its
    /// *scheduled* arrival instant — monitor-side dispatch lag counts as
    /// queueing delay — so TTFT/queue metrics measure what a client of
    /// the serving fleet would see. Tasks accumulate across calls until
    /// the next `run_streaming`.
    pub fn submit(&mut self, offset_secs: f64, tasks: Vec<SampleTask>) {
        let at = if offset_secs.is_finite() { offset_secs.max(0.0) } else { 0.0 };
        for t in tasks {
            self.arrival_queue.push((at, t));
        }
    }

    /// Process every submitted arrival to completion (continuous
    /// batching): the monitor drains the arrival queue against the wall
    /// clock between decode-step events, dispatching each due task to the
    /// least-loaded instance — the same admission policy the virtual
    /// cluster uses, with the per-worker waiting queue as the backlog (no
    /// hard refusal on hardware: memory pressure is bounded by the
    /// compiled batch buckets, not by sample count).
    ///
    /// Reallocation stays live throughout, but while every instance sits
    /// at its 4×-capacity budget the policy reports a backlog
    /// ([`Reallocator::note_backlog`]) and holds off: arrivals, not
    /// migrations, fill the deficits.
    pub fn run_streaming(&mut self) -> Result<GenerationReport> {
        let n_inst = self.cmd_txs.len();
        let mut sorted = std::mem::take(&mut self.arrival_queue);
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
        // Consume front-to-back without cloning tasks at dispatch.
        let mut queue: std::collections::VecDeque<(f64, SampleTask)> = sorted.into();
        let expected = queue.len();
        // Drain stale events from a previous batch; reset the faulty
        // relay's per-batch state.
        while self.ev_rx.try_recv().is_ok() {}
        self.held.clear();
        self.retransmits = 0;
        self.handshake_aborts = 0;
        let faults_at_start = self.link.stats();

        let t0 = Instant::now();
        let cap = self
            .manifest
            .batch_buckets
            .iter()
            .max()
            .copied()
            .unwrap_or(1)
            * 4;
        let caps: Vec<usize> = vec![cap; n_inst];
        let mut counts = vec![0usize; n_inst];
        let mut finished_counts = vec![0usize; n_inst];
        let mut step: u64 = 0;
        let mut migrations = 0u64;
        let mut srd_secs = 0.0f64;
        let mut reported = false;
        let mut done_reports: BTreeMap<usize, InstanceReport> = BTreeMap::new();
        let mut all_finished: Vec<FinishedSample> = Vec::new();
        let mut refusals = 0u64;
        let mut ticker = ReallocTicker::new(self.cfg.realloc.period_secs);

        if expected == 0 {
            return Ok(assemble_report(
                Vec::new(),
                BTreeMap::new(),
                0.0,
                0,
                0,
                self.realloc.decisions,
                0.0,
                0,
                0,
                (0, 0),
            ));
        }

        let stall = Duration::from_secs(900);
        let mut last_event = Instant::now();
        loop {
            self.pump_retransmits();
            // Dispatch every arrival that is due, stamping submission at
            // dispatch time. Least-loaded under the memory budget first;
            // when the whole fleet is at budget, still least-loaded (the
            // worker's waiting queue is the backlog).
            let now = t0.elapsed().as_secs_f64();
            while let Some(&(due, _)) = queue.front() {
                if due > now {
                    break;
                }
                let (_, mut task) = queue.pop_front().expect("front was Some");
                // Stamp the *scheduled* arrival instant, not the dispatch
                // instant: if the monitor dispatches late (busy pumping
                // events under load), that lag is real client-visible
                // queueing delay and must stay in the TTFT/queue metrics
                // — matching the sim plane, which anchors latency at the
                // arrival-event time.
                task.submitted_at = Some(t0 + Duration::from_secs_f64(due));
                let dest = (0..n_inst)
                    .filter(|&i| counts[i] < cap)
                    .min_by_key(|&i| counts[i])
                    .or_else(|| (0..n_inst).min_by_key(|&i| counts[i]))
                    .expect("service always has at least one worker");
                counts[dest] += 1; // optimistic; refreshed by Progress
                let _ = self.cmd_txs[dest].send(Cmd::Add(vec![task]));
            }

            // Wake in time for the next arrival — or the retransmit
            // period on a faulty relay; otherwise the generous
            // first-step compile timeout applies (see run_batch).
            let mut timeout = if let Some(&(due, _)) = queue.front() {
                let wait = due - t0.elapsed().as_secs_f64();
                Duration::from_secs_f64(wait.clamp(0.001, 900.0))
            } else {
                stall
            };
            if self.faulty {
                timeout = timeout.min(Duration::from_secs_f64(self.retransmit_period()));
            }
            let ev = match self.ev_rx.recv_timeout(timeout) {
                Ok(e) => e,
                Err(_) if !queue.is_empty() => continue, // arrival due
                Err(_) => {
                    if last_event.elapsed() >= stall {
                        return Err(anyhow!(
                            "streaming generation stalled: {} / {expected} finished after {:?}",
                            finished_counts.iter().sum::<usize>(),
                            t0.elapsed()
                        ));
                    }
                    continue;
                }
            };
            last_event = Instant::now();
            let Some(ev) = self.relay_protocol_event(ev, &mut refusals) else {
                continue;
            };
            match ev {
                Event::Progress {
                    instance,
                    sample_count,
                    throughput,
                    finished,
                } => {
                    counts[instance] = sample_count;
                    finished_counts[instance] = finished;
                    step += 1;
                    self.realloc.observe(sample_count.max(1), throughput);
                    // Occupancy is time-varying here: while the fleet is
                    // saturated, arrivals (not migrations) fill deficits.
                    let saturated = counts.iter().all(|&c| c >= cap);
                    self.realloc.note_backlog(saturated as usize);

                    let due = if ticker.timed() {
                        ticker.due(t0.elapsed().as_secs_f64())
                            && self.realloc.inefficiency(&counts)
                    } else {
                        self.realloc.should_decide(step, &counts)
                    };
                    if self.cfg.realloc.enabled && !reported && due {
                        let sw = Instant::now();
                        self.realloc.refit_threshold();
                        migrations += self.dispatch_plan(step, &counts, &caps);
                        srd_secs += sw.elapsed().as_secs_f64();
                    }

                    if !reported
                        && queue.is_empty()
                        && finished_counts.iter().sum::<usize>() >= expected
                    {
                        reported = true;
                        for tx in &self.cmd_txs {
                            let _ = tx.send(Cmd::Report);
                        }
                    }
                }
                other => {
                    if Self::absorb_done(other, &mut all_finished, &mut done_reports, n_inst)? {
                        break;
                    }
                }
            }
        }
        self.realloc.note_backlog(0);
        self.settle_held_orders();

        let faults = self.link.stats();
        Ok(assemble_report(
            all_finished,
            done_reports,
            t0.elapsed().as_secs_f64(),
            migrations,
            refusals,
            self.realloc.decisions,
            srd_secs,
            self.retransmits,
            self.handshake_aborts,
            (faults.0 - faults_at_start.0, faults.1 - faults_at_start.1),
        ))
    }

    /// Stop all workers and join.
    pub fn shutdown(self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        for j in self.joins {
            let _ = j.join();
        }
    }
}

/// One-shot convenience wrapper (start → run_batch → shutdown).
pub fn run_generation(
    artifacts_dir: &std::path::Path,
    cfg: &RunConfig,
    mode: DecodeMode,
    tasks: Vec<SampleTask>,
    target_weights: &[HostTensor],
    draft_weights: &[HostTensor],
) -> Result<GenerationReport> {
    let mut svc =
        GenerationService::start(artifacts_dir, cfg, mode, target_weights, draft_weights)?;
    let report = svc.run_batch(tasks)?;
    svc.shutdown();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(wall_secs: f64, tokens: u64, finished: usize) -> GenerationReport {
        GenerationReport {
            finished: (0..finished)
                .map(|i| FinishedSample {
                    id: i as u64,
                    prompt: vec![1],
                    response: vec![2],
                    rounds: 1,
                    drafts_accepted: 0,
                    drafts_proposed: 0,
                    latency: None,
                })
                .collect(),
            instances: Vec::new(),
            wall_secs,
            migrations: 0,
            migration_refusals: 0,
            realloc_decisions: 0,
            srd_secs: 0.0,
            protocol: ProtocolCounters::default(),
            total_tokens: tokens,
            latency: LatencySummary::default(),
        }
    }

    #[test]
    fn throughput_guards_zero_elapsed() {
        let r = report(0.0, 100, 4);
        assert_eq!(r.throughput_tokens(), 0.0);
        assert_eq!(r.throughput_samples(), 0.0);
        let neg = report(-1.0, 100, 4);
        assert_eq!(neg.throughput_tokens(), 0.0);
    }

    #[test]
    fn throughput_normal_case() {
        let r = report(2.0, 100, 4);
        assert!((r.throughput_tokens() - 50.0).abs() < 1e-9);
        assert!((r.throughput_samples() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn start_accepts_faulty_transport_but_rejects_crash_injection() {
        // Since the relay fault port, a `[transport]` section is honored
        // by the monitor itself — start() no longer rejects it (the
        // error below comes from the missing artifacts, later in start).
        let mut cfg = RunConfig::default();
        cfg.set("transport.stage2.drop_prob", "0.5").unwrap();
        let err = GenerationService::start(
            std::path::Path::new("/nonexistent"),
            &cfg,
            DecodeMode::Ar,
            &[],
            &[],
        )
        .err()
        .expect("nonexistent artifacts must still fail");
        let msg = format!("{err:#}");
        assert!(
            !msg.contains("transport"),
            "faulty transport must be accepted now: {msg}"
        );
        // Whole-instance crash injection stays simulation-only: a
        // non-zero `[crash]` section errors loudly, before artifacts.
        let mut cfg2 = RunConfig::default();
        cfg2.set("crash.rate_per_sec", "0.5").unwrap();
        let err2 = GenerationService::start(
            std::path::Path::new("/nonexistent"),
            &cfg2,
            DecodeMode::Ar,
            &[],
            &[],
        )
        .err()
        .expect("crash injection must be rejected");
        let msg2 = format!("{err2:#}");
        assert!(msg2.contains("crash"), "{msg2}");
    }

    #[test]
    fn realloc_ticker_fires_on_period_grid() {
        let mut t = ReallocTicker::new(0.5);
        assert!(t.timed());
        assert!(!t.due(0.0));
        assert!(!t.due(0.49));
        assert!(t.due(0.5), "first tick at one period");
        assert!(!t.due(0.6), "tick consumed until the next period");
        assert!(t.due(1.01));
    }

    #[test]
    fn realloc_ticker_collapses_missed_periods() {
        // A monitor that slept through several periods (one long decode
        // step) gets exactly one catch-up tick, re-anchored on the grid.
        let mut t = ReallocTicker::new(0.25);
        assert!(t.due(1.6), "first poll after 6+ periods fires once");
        assert!(!t.due(1.7), "missed periods are not replayed");
        assert!(t.due(1.75), "next grid point still fires");
    }

    #[test]
    fn realloc_ticker_disabled_by_nonpositive_period() {
        for p in [0.0, -1.0, f64::NAN] {
            let mut t = ReallocTicker::new(p);
            assert!(!t.timed());
            assert!(!t.due(1e9));
        }
    }

    #[test]
    fn realloc_ticker_tolerates_clock_jump_backwards() {
        // A clock that jumps backwards (NTP step, suspend/resume skew)
        // must not fire spurious ticks or wedge the schedule: earlier
        // instants simply report not-due, and the original grid resumes
        // once the clock passes the armed deadline again.
        let mut t = ReallocTicker::new(1.0);
        assert!(t.due(1.0), "first grid point");
        assert!(!t.due(0.25), "backwards jump is not due");
        assert!(!t.due(0.9), "still before the armed deadline");
        assert!(t.due(2.0), "forward progress resumes the grid");
        assert!(!t.due(1.5), "another backwards jump after a tick");
        assert!(t.due(3.0));
    }

    #[test]
    fn realloc_ticker_multi_period_catchup_is_one_tick_on_the_grid() {
        // Sleeping through MANY periods (a minutes-long first compile)
        // yields exactly one catch-up tick, and the next deadline is the
        // next grid point — not `now + period` (no drift) and not a
        // burst of replayed ticks.
        let mut t = ReallocTicker::new(0.5);
        assert!(t.due(10.26), "one catch-up tick after 20+ missed periods");
        assert!(!t.due(10.26), "same instant: the tick was consumed");
        assert!(!t.due(10.49), "not due before the next grid point");
        assert!(t.due(10.5), "grid stays anchored at multiples of 0.5");
    }
}
