//! Multi-instance generation driver (Fig 6 workflow).
//!
//! One worker thread per generation instance (each owns its PJRT client —
//! the "one client per GPU" topology), a monitor loop in the caller's
//! thread, and message-passing for the reallocation/migration protocol:
//!
//! ```text
//!   monitor                worker s                worker d
//!     │  MigrateOut(s→d,k)   │                        │
//!     ├──────────────────────▶ pick victims           │
//!     │        AllocReq      │                        │
//!     ◀──────────────────────┤                        │
//!     ├──── DeliverAllocReq ─────────────────────────▶ capacity check
//!     │        AllocAck      │                        │
//!     ◀───────────────────────────────────────────────┤
//!     ├──── AllocAck(ok) ────▶ send Stage1 (bulk KV)  │
//!     │        Stage1        │   …keeps decoding…     │
//!     ◀──────────────────────┤                        │
//!     ├──── DeliverStage1 ───────────────────────────▶ unpack (phase 3)
//!     │        Stage2        │ (next step boundary)   │
//!     ◀──────────────────────┤ delta + control        │
//!     ├──── DeliverStage2 ───────────────────────────▶ resume samples
//! ```
//!
//! The endpoint state machine (victim picking, handshake sequencing,
//! Stage-1/Stage-2 packing and restore) lives in
//! [`InstanceCore`](crate::coordinator::core::InstanceCore), shared with
//! the virtual-clock simulation cluster — the worker threads here only
//! pump commands/events between the monitor and that endpoint.
//!
//! Initial allocation is sequential round-robin (paper §4: "training
//! samples are first sequentially allocated to the generation instances").
//!
//! Two entry points share the workers: [`GenerationService::run_batch`]
//! (batch-synchronous, the paper's workload) and
//! [`GenerationService::submit`] + [`GenerationService::run_streaming`]
//! (continuous batching: the monitor drains a wall-clock arrival queue
//! between decode-step events, dispatching each task to the least-loaded
//! instance — mirroring the virtual cluster's admission policy — and the
//! report carries per-sample TTFT/TPOT/queueing-delay percentiles).

use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::core::{AckOutcome, MigrateStart, Stage1Msg, Stage2Msg};
use crate::coordinator::instance::{
    DecodeMode, FinishedSample, GenerationInstance, PjrtBackend, SampleTask,
};
use crate::coordinator::metrics::{InstanceMetrics, LatencySummary};
use crate::coordinator::migration::AllocRequest;
use crate::coordinator::reallocator::Reallocator;
use crate::runtime::{HostTensor, Manifest, ModelStore};
use crate::utils::stats::Ema;

// ---------------------------------------------------------------------------
// Protocol messages
// ---------------------------------------------------------------------------

enum Cmd {
    Add(Vec<SampleTask>),
    MigrateOut { to: usize, count: usize, order: u64 },
    AllocAck { order: u64, ok: bool },
    DeliverAllocReq(AllocRequest),
    DeliverStage1(Stage1Msg<PjrtBackend>),
    DeliverStage2(Stage2Msg<PjrtBackend>),
    /// Source-side confirmation that `order`'s Stage-2 was relayed:
    /// releases the endpoint's limbo copy. The monitor's channels are
    /// reliable FIFO, so relay time is commit time on this plane.
    ConfirmOrder(u64),
    /// Broadcast fresh actor/draft weights (next RLHF iteration).
    UpdateWeights(Vec<HostTensor>, Vec<HostTensor>),
    /// Emit a Done report for the current batch but keep running.
    Report,
    Stop,
}

enum Event {
    Progress {
        instance: usize,
        sample_count: usize,
        throughput: f64,
        finished: usize,
    },
    AllocReq {
        to: usize,
        req: AllocRequest,
    },
    AllocAck {
        to_source: usize,
        order: u64,
        ok: bool,
    },
    Stage1 {
        to: usize,
        pkt: Stage1Msg<PjrtBackend>,
    },
    Stage2 {
        to: usize,
        pkt: Stage2Msg<PjrtBackend>,
    },
    MigrationRefused,
    Done {
        instance: usize,
        finished: Vec<FinishedSample>,
        metrics: Box<InstanceMetrics>,
        fig7_curve: Vec<(f64, f64, u64)>,
        accept_corr: f64,
        tsd_cache_hits: u64,
        tsd_cache_misses: u64,
    },
    Fatal {
        instance: usize,
        error: String,
    },
}

/// Per-instance summary returned to the caller.
pub struct InstanceReport {
    /// Instance id.
    pub id: usize,
    /// Per-stage timing and counters.
    pub metrics: InstanceMetrics,
    /// The learned Fig-7 acceptance curve rows.
    pub fig7_curve: Vec<(f64, f64, u64)>,
    /// Pearson correlation of the learned acceptance curve.
    pub accept_corr: f64,
    /// `t_sd` bucket-cache hits (§5.2 cache effectiveness).
    pub tsd_cache_hits: u64,
    /// `t_sd` bucket-cache misses.
    pub tsd_cache_misses: u64,
}

/// Whole-run summary.
pub struct GenerationReport {
    /// Completed samples across the fleet.
    pub finished: Vec<FinishedSample>,
    /// Per-instance reports, ordered by instance id.
    pub instances: Vec<InstanceReport>,
    /// Wall seconds from dispatch to the last report.
    pub wall_secs: f64,
    /// Migration orders issued by the monitor.
    pub migrations: u64,
    /// Migration orders that ended in refusal.
    pub migration_refusals: u64,
    /// Reallocation decisions taken.
    pub realloc_decisions: u64,
    /// Seconds the monitor spent inside reallocation decisions (§7.7 SRD).
    pub srd_secs: f64,
    /// Total generated tokens across instances.
    pub total_tokens: u64,
    /// Per-sample serving-latency percentiles (queueing delay, TTFT,
    /// TPOT) over samples that carried a submission stamp — i.e. the
    /// streaming [`GenerationService::submit`] path; empty for plain
    /// batch runs.
    pub latency: LatencySummary,
}

impl GenerationReport {
    /// Tokens per wall second (0 when no time elapsed).
    pub fn throughput_tokens(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / self.wall_secs
        }
    }

    /// Finished samples per wall second (0 when no time elapsed).
    pub fn throughput_samples(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.finished.len() as f64 / self.wall_secs
        }
    }
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

struct Worker {
    core: GenerationInstance,
    cmds: Receiver<Cmd>,
    events: Sender<Event>,
    throughput: Ema,
    last_tokens: u64,
}

impl Worker {
    fn run(mut self) {
        loop {
            // Drain commands.
            loop {
                match self.cmds.try_recv() {
                    Ok(Cmd::Stop) => {
                        self.finishup();
                        return;
                    }
                    Ok(cmd) => {
                        if let Err(e) = self.handle(cmd) {
                            let _ = self.events.send(Event::Fatal {
                                instance: self.core.id,
                                error: format!("{e:#}"),
                            });
                            return;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        self.finishup();
                        return;
                    }
                }
            }

            if self.core.is_idle() {
                // Flush any Stage-2 that was waiting on a step boundary
                // (all victims may have finished during the overlap step).
                self.pump_stage2();
                // Nothing to do: block briefly for commands.
                match self.cmds.recv_timeout(Duration::from_millis(5)) {
                    Ok(Cmd::Stop) => {
                        self.finishup();
                        return;
                    }
                    Ok(cmd) => {
                        if let Err(e) = self.handle(cmd) {
                            let _ = self.events.send(Event::Fatal {
                                instance: self.core.id,
                                error: format!("{e:#}"),
                            });
                            return;
                        }
                    }
                    Err(_) => {}
                }
                continue;
            }

            let t0 = Instant::now();
            if let Err(e) = self.core.step() {
                let _ = self.events.send(Event::Fatal {
                    instance: self.core.id,
                    error: format!("{e:#}"),
                });
                return;
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let new_tokens = self.core.metrics.tokens_out - self.last_tokens;
            self.last_tokens = self.core.metrics.tokens_out;
            let tp = self.throughput.update(new_tokens as f64 / dt);

            // Stage 2 of an in-flight outbound migration fires at the step
            // boundary after Stage 1 (the overlapped decode step).
            self.pump_stage2();

            let _ = self.events.send(Event::Progress {
                instance: self.core.id,
                sample_count: self.core.sample_count(),
                throughput: tp,
                finished: self.core.finished.len(),
            });
        }
    }

    /// Emit every pending Stage-2 packet the endpoint has ready —
    /// batched multi-destination order sets can have several handshakes
    /// reach their step boundary at once.
    fn pump_stage2(&mut self) {
        while let Some(pkt) = self.core.poll_stage2() {
            let _ = self.events.send(Event::Stage2 { to: pkt.to, pkt });
        }
    }

    fn handle(&mut self, cmd: Cmd) -> Result<()> {
        match cmd {
            Cmd::Add(tasks) => {
                for t in tasks {
                    self.core.add_task(t);
                }
            }
            Cmd::MigrateOut { to, count, order } => {
                match self.core.begin_migration(to, count, order) {
                    MigrateStart::Refused => {
                        let _ = self.events.send(Event::MigrationRefused);
                    }
                    MigrateStart::QueueOnly(pkt) => {
                        let _ = self.events.send(Event::Stage2 { to: pkt.to, pkt });
                    }
                    MigrateStart::AllocReq(req) => {
                        let _ = self.events.send(Event::AllocReq { to, req });
                    }
                }
            }
            Cmd::AllocAck { order, ok } => match self.core.handle_alloc_ack(order, ok) {
                AckOutcome::NoPending => {}
                AckOutcome::Refused => {
                    let _ = self.events.send(Event::MigrationRefused);
                }
                AckOutcome::Stage1(pkt) => {
                    let _ = self.events.send(Event::Stage1 { to: pkt.to, pkt });
                }
            },
            Cmd::DeliverAllocReq(req) => {
                let ok = self.core.handle_alloc_req(&req);
                let _ = self.events.send(Event::AllocAck {
                    to_source: req.from_instance,
                    order: req.order,
                    ok,
                });
            }
            Cmd::DeliverStage1(pkt) => self.core.handle_stage1(pkt)?,
            Cmd::DeliverStage2(pkt) => {
                self.core.handle_stage2(pkt)?;
            }
            Cmd::ConfirmOrder(order) => self.core.confirm_order(order),
            Cmd::UpdateWeights(tw, dw) => {
                self.core.backend.target.set_weights(&tw)?;
                self.core.backend.draft.set_weights(&dw)?;
            }
            Cmd::Report => self.report_batch(),
            Cmd::Stop => unreachable!("handled by caller"),
        }
        Ok(())
    }

    /// Emit a Done event for the finished-so-far batch without stopping.
    fn report_batch(&mut self) {
        let fig7_curve = self.core.accept_pred.curve();
        let accept_corr = self.core.accept_pred.correlation();
        let _ = self.events.send(Event::Done {
            instance: self.core.id,
            finished: std::mem::take(&mut self.core.finished),
            metrics: Box::new(self.core.metrics.clone()),
            fig7_curve,
            accept_corr,
            tsd_cache_hits: self.core.tsd_pred.cache_hits,
            tsd_cache_misses: self.core.tsd_pred.cache_misses,
        });
    }

    fn finishup(mut self) {
        self.report_batch();
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Wall-clock reallocation cadence for the threaded monitor loop — the
/// real-plane port of `ClusterConfig::realloc_period_secs`. With a
/// period set (`realloc.period_secs > 0`), decisions fire on elapsed
/// virtual-wall-time ticks instead of the step-counter cadence, which is
/// the meaningful schedule once instances step at different rates.
struct ReallocTicker {
    period: Option<f64>,
    next_at: f64,
}

impl ReallocTicker {
    /// A non-positive (or NaN) period disables the timed cadence — the
    /// step-counter cadence stays in charge.
    fn new(period_secs: f64) -> Self {
        let period = (period_secs > 0.0).then_some(period_secs);
        ReallocTicker { period, next_at: period.unwrap_or(0.0) }
    }

    /// True when the timed cadence (rather than the step cadence)
    /// governs decision scheduling.
    fn timed(&self) -> bool {
        self.period.is_some()
    }

    /// One decision tick is due at `now` (seconds since run start)?
    /// Fires at most once per call; a monitor that slept through several
    /// periods (one long decode step) gets a single catch-up tick, and
    /// the schedule stays anchored to the period grid (no drift).
    fn due(&mut self, now: f64) -> bool {
        let Some(p) = self.period else { return false };
        if now < self.next_at {
            return false;
        }
        while self.next_at <= now {
            self.next_at += p;
        }
        true
    }
}

/// Assemble the final [`GenerationReport`] from the monitor accumulators
/// (shared by `run_batch` and `run_streaming`).
fn assemble_report(
    all_finished: Vec<FinishedSample>,
    done_reports: BTreeMap<usize, InstanceReport>,
    wall_secs: f64,
    migrations: u64,
    migration_refusals: u64,
    realloc_decisions: u64,
    srd_secs: f64,
) -> GenerationReport {
    let total_tokens = done_reports.values().map(|r| r.metrics.tokens_out).sum();
    let latencies: Vec<_> = all_finished.iter().filter_map(|f| f.latency).collect();
    GenerationReport {
        finished: all_finished,
        instances: done_reports.into_values().collect(),
        wall_secs,
        migrations,
        migration_refusals,
        realloc_decisions,
        srd_secs,
        total_tokens,
        latency: LatencySummary::from_samples(&latencies),
    }
}

/// Persistent multi-instance generation service.
///
/// Worker threads (each with its own PJRT client and compiled executables)
/// live across RLHF iterations: [`GenerationService::run_batch`] processes
/// one generation stage, [`GenerationService::update_weights`] broadcasts
/// the freshly trained actor/draft weights, and compiled artifacts are
/// reused — exactly how a serving fleet amortizes warmup.
pub struct GenerationService {
    cfg: RunConfig,
    manifest: Manifest,
    cmd_txs: Vec<Sender<Cmd>>,
    ev_rx: Receiver<Event>,
    joins: Vec<std::thread::JoinHandle<()>>,
    realloc: Reallocator,
    mode: DecodeMode,
    /// Streaming arrival queue: (offset seconds from `run_streaming`
    /// start, task), fed by [`GenerationService::submit`].
    arrival_queue: Vec<(f64, SampleTask)>,
    /// Next cluster-unique migration-order sequence number. Monotone
    /// across batches, so a stale Stage-2 from a previous batch can
    /// never collide with a live order's dedup key.
    next_order: u64,
}

impl GenerationService {
    /// Spawn workers. `weights` cross the thread boundary as host tensors
    /// (`xla::Literal` is not Send); each worker materializes its stores.
    pub fn start(
        artifacts_dir: &std::path::Path,
        cfg: &RunConfig,
        mode: DecodeMode,
        target_weights: &[HostTensor],
        draft_weights: &[HostTensor],
    ) -> Result<GenerationService> {
        // The real plane's carrier is in-process channels — reliable
        // FIFO by construction, so a `[transport]` fault model cannot
        // be honored here. Reject it loudly rather than silently
        // ignoring the config (fault injection on the threaded driver
        // is a ROADMAP follow-up; the simulated plane honors the same
        // section via `ClusterConfig::transport`).
        if !cfg.transport.is_perfect() {
            return Err(anyhow!(
                "[transport] fault probabilities are set, but the threaded driver's \
                 in-process channels are reliable and cannot inject faults; use the \
                 simulation plane (ClusterConfig::transport) for fault schedules"
            ));
        }
        let n_inst = cfg.rlhf.instances.max(1);
        let manifest = Manifest::load(artifacts_dir)?;
        let (ev_tx, ev_rx) = channel::<Event>();
        let mut cmd_txs: Vec<Sender<Cmd>> = Vec::new();
        let mut joins = Vec::new();

        for i in 0..n_inst {
            let (tx, rx) = channel::<Cmd>();
            cmd_txs.push(tx);
            let ev = ev_tx.clone();
            let man = manifest.clone();
            let cfgc = cfg.clone();
            let tw: Vec<HostTensor> = target_weights.to_vec();
            let dw: Vec<HostTensor> = draft_weights.to_vec();
            let seed = cfg.seed ^ (0xABCD + i as u64);
            joins.push(std::thread::spawn(move || {
                let man = Rc::new(man);
                let mut target = match ModelStore::init(&man, "target", 0) {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = ev.send(Event::Fatal { instance: i, error: format!("{e:#}") });
                        return;
                    }
                };
                let mut draft = ModelStore::init(&man, "draft", 0).unwrap();
                if target.set_weights(&tw).is_err() || draft.set_weights(&dw).is_err() {
                    let _ = ev.send(Event::Fatal {
                        instance: i,
                        error: "weight broadcast failed".into(),
                    });
                    return;
                }
                let inst =
                    match GenerationInstance::new(i, man, target, draft, cfgc, mode, seed) {
                        Ok(x) => x,
                        Err(e) => {
                            let _ = ev
                                .send(Event::Fatal { instance: i, error: format!("{e:#}") });
                            return;
                        }
                    };
                Worker {
                    core: inst,
                    cmds: rx,
                    events: ev,
                    throughput: Ema::new(0.3),
                    last_tokens: 0,
                }
                .run();
            }));
        }
        Ok(GenerationService {
            cfg: cfg.clone(),
            manifest,
            cmd_txs,
            ev_rx,
            joins,
            realloc: Reallocator::new(cfg.realloc.threshold, cfg.realloc.cooldown as u64),
            mode,
            arrival_queue: Vec::new(),
            next_order: 1,
        })
    }

    /// The decode mode every worker runs.
    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    /// Broadcast fresh actor/draft weights to every worker.
    pub fn update_weights(
        &self,
        target_weights: &[HostTensor],
        draft_weights: &[HostTensor],
    ) -> Result<()> {
        for tx in &self.cmd_txs {
            tx.send(Cmd::UpdateWeights(
                target_weights.to_vec(),
                draft_weights.to_vec(),
            ))
            .map_err(|_| anyhow!("worker channel closed"))?;
        }
        Ok(())
    }

    /// Fold a worker's terminal event into the monitor's accumulators:
    /// `Done` collects the finished samples + per-instance report (true
    /// once every instance reported), `Fatal` aborts. Shared by
    /// `run_batch` and `run_streaming` — with
    /// [`Self::relay_protocol_event`] this keeps the two monitor loops'
    /// shared logic in one place.
    fn absorb_done(
        ev: Event,
        all_finished: &mut Vec<FinishedSample>,
        done_reports: &mut BTreeMap<usize, InstanceReport>,
        n_inst: usize,
    ) -> Result<bool> {
        match ev {
            Event::Done {
                instance,
                finished,
                metrics,
                fig7_curve,
                accept_corr,
                tsd_cache_hits,
                tsd_cache_misses,
            } => {
                all_finished.extend(finished);
                done_reports.insert(
                    instance,
                    InstanceReport {
                        id: instance,
                        metrics: *metrics,
                        fig7_curve,
                        accept_corr,
                        tsd_cache_hits,
                        tsd_cache_misses,
                    },
                );
                Ok(done_reports.len() == n_inst)
            }
            Event::Fatal { instance, error } => {
                Err(anyhow!("instance {instance} failed: {error}"))
            }
            _ => unreachable!("only terminal events reach absorb_done"),
        }
    }

    /// Relay a pure §6.2 protocol event between workers (AllocReq/Ack,
    /// Stage 1/2, refusal accounting). Returns the event back when it is
    /// not a relay (Progress/Done/Fatal) so the calling monitor loop can
    /// apply its own bookkeeping — `run_batch` and `run_streaming` share
    /// this pump so a protocol change cannot diverge between them.
    fn relay_protocol_event(&mut self, ev: Event, refusals: &mut u64) -> Option<Event> {
        match ev {
            Event::AllocReq { to, req } => {
                let _ = self.cmd_txs[to].send(Cmd::DeliverAllocReq(req));
                None
            }
            Event::AllocAck { to_source, order, ok } => {
                let _ = self.cmd_txs[to_source].send(Cmd::AllocAck { order, ok });
                None
            }
            Event::Stage1 { to, pkt } => {
                let _ = self.cmd_txs[to].send(Cmd::DeliverStage1(pkt));
                None
            }
            Event::Stage2 { to, pkt } => {
                let (src, order) = (pkt.from, pkt.order);
                let _ = self.cmd_txs[to].send(Cmd::DeliverStage2(pkt));
                // In-process channels are reliable FIFO: once the Stage-2
                // is relayed it *will* apply, so the source can release
                // its limbo copy now.
                let _ = self.cmd_txs[src].send(Cmd::ConfirmOrder(order));
                None
            }
            Event::MigrationRefused => {
                *refusals += 1;
                self.realloc.report_refusal();
                None
            }
            other => Some(other),
        }
    }

    /// Plan one reallocation decision (classic pairing, or the batched
    /// multi-destination order set under `realloc.multi_dest`) and
    /// dispatch each order to its source worker with a fresh
    /// cluster-unique order id. The workers' hardened endpoints run the
    /// handshakes concurrently — one batched set opens several at once.
    /// Returns the number of orders issued.
    fn dispatch_plan(&mut self, step: u64, counts: &[usize], caps: &[usize]) -> u64 {
        let plan = if self.cfg.realloc.multi_dest {
            self.realloc.decide_batched(step, counts, caps)
        } else {
            self.realloc.decide(step, counts, caps)
        };
        let mut issued = 0;
        for m in plan {
            let order = self.next_order;
            self.next_order += 1;
            issued += 1;
            let _ = self.cmd_txs[m.from].send(Cmd::MigrateOut {
                to: m.to,
                count: m.count,
                order,
            });
        }
        issued
    }

    /// Process one batch of samples to completion (one generation stage).
    pub fn run_batch(&mut self, tasks: Vec<SampleTask>) -> Result<GenerationReport> {
        let n_inst = self.cmd_txs.len();
        let expected = tasks.len();
        // Batch-synchronous: no admission backlog can gate reallocation
        // (clears any stale gate from an aborted streaming run).
        self.realloc.note_backlog(0);
        // Drain stale events from a previous batch.
        while self.ev_rx.try_recv().is_ok() {}

        // Sequential initial allocation (§4).
        let mut batches: Vec<Vec<SampleTask>> = vec![Vec::new(); n_inst];
        for (i, t) in tasks.into_iter().enumerate() {
            batches[i % n_inst].push(t);
        }
        for (i, b) in batches.into_iter().enumerate() {
            let _ = self.cmd_txs[i].send(Cmd::Add(b));
        }

        let t0 = Instant::now();
        let mut counts = vec![0usize; n_inst];
        let mut finished_counts = vec![0usize; n_inst];
        let mut step: u64 = 0;
        let mut migrations = 0u64;
        let mut srd_secs = 0.0f64;
        let mut reported = false;
        let mut done_reports: BTreeMap<usize, InstanceReport> = BTreeMap::new();
        let mut all_finished: Vec<FinishedSample> = Vec::new();
        let mut refusals = 0u64;
        let mut ticker = ReallocTicker::new(self.cfg.realloc.period_secs);

        loop {
            // Generous stall timeout: a worker's FIRST step lazily
            // compiles several XLA executables, which can take minutes on
            // a small shared-CPU box.
            let ev = match self.ev_rx.recv_timeout(Duration::from_secs(900)) {
                Ok(e) => e,
                Err(_) => {
                    return Err(anyhow!(
                        "generation stalled: {} / {expected} finished after {:?}",
                        finished_counts.iter().sum::<usize>(),
                        t0.elapsed()
                    ))
                }
            };
            let Some(ev) = self.relay_protocol_event(ev, &mut refusals) else {
                continue;
            };
            match ev {
                Event::Progress {
                    instance,
                    sample_count,
                    throughput,
                    finished,
                } => {
                    counts[instance] = sample_count;
                    finished_counts[instance] = finished;
                    step += 1;
                    self.realloc.observe(sample_count.max(1), throughput);

                    // Timed cadence (realloc.period_secs) fires on the
                    // wall clock; otherwise the step-counter cadence.
                    let due = if ticker.timed() {
                        ticker.due(t0.elapsed().as_secs_f64())
                            && self.realloc.inefficiency(&counts)
                    } else {
                        self.realloc.should_decide(step, &counts)
                    };
                    if self.cfg.realloc.enabled && !reported && due {
                        let sw = Instant::now();
                        self.realloc.refit_threshold();
                        let caps: Vec<usize> = vec![
                            self.manifest
                                .batch_buckets
                                .iter()
                                .max()
                                .copied()
                                .unwrap_or(1)
                                * 4;
                            n_inst
                        ];
                        migrations += self.dispatch_plan(step, &counts, &caps);
                        srd_secs += sw.elapsed().as_secs_f64();
                    }

                    if !reported && finished_counts.iter().sum::<usize>() >= expected {
                        reported = true;
                        for tx in &self.cmd_txs {
                            let _ = tx.send(Cmd::Report);
                        }
                    }
                }
                other => {
                    if Self::absorb_done(other, &mut all_finished, &mut done_reports, n_inst)? {
                        break;
                    }
                }
            }
        }

        Ok(assemble_report(
            all_finished,
            done_reports,
            t0.elapsed().as_secs_f64(),
            migrations,
            refusals,
            self.realloc.decisions,
            srd_secs,
        ))
    }

    /// Queue tasks for the streaming path: they will be dispatched
    /// `offset_secs` after [`GenerationService::run_streaming`] starts
    /// (0 = immediately). Each task's `submitted_at` stamp is its
    /// *scheduled* arrival instant — monitor-side dispatch lag counts as
    /// queueing delay — so TTFT/queue metrics measure what a client of
    /// the serving fleet would see. Tasks accumulate across calls until
    /// the next `run_streaming`.
    pub fn submit(&mut self, offset_secs: f64, tasks: Vec<SampleTask>) {
        let at = if offset_secs.is_finite() { offset_secs.max(0.0) } else { 0.0 };
        for t in tasks {
            self.arrival_queue.push((at, t));
        }
    }

    /// Process every submitted arrival to completion (continuous
    /// batching): the monitor drains the arrival queue against the wall
    /// clock between decode-step events, dispatching each due task to the
    /// least-loaded instance — the same admission policy the virtual
    /// cluster uses, with the per-worker waiting queue as the backlog (no
    /// hard refusal on hardware: memory pressure is bounded by the
    /// compiled batch buckets, not by sample count).
    ///
    /// Reallocation stays live throughout, but while every instance sits
    /// at its 4×-capacity budget the policy reports a backlog
    /// ([`Reallocator::note_backlog`]) and holds off: arrivals, not
    /// migrations, fill the deficits.
    pub fn run_streaming(&mut self) -> Result<GenerationReport> {
        let n_inst = self.cmd_txs.len();
        let mut sorted = std::mem::take(&mut self.arrival_queue);
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
        // Consume front-to-back without cloning tasks at dispatch.
        let mut queue: std::collections::VecDeque<(f64, SampleTask)> = sorted.into();
        let expected = queue.len();
        // Drain stale events from a previous batch.
        while self.ev_rx.try_recv().is_ok() {}

        let t0 = Instant::now();
        let cap = self
            .manifest
            .batch_buckets
            .iter()
            .max()
            .copied()
            .unwrap_or(1)
            * 4;
        let caps: Vec<usize> = vec![cap; n_inst];
        let mut counts = vec![0usize; n_inst];
        let mut finished_counts = vec![0usize; n_inst];
        let mut step: u64 = 0;
        let mut migrations = 0u64;
        let mut srd_secs = 0.0f64;
        let mut reported = false;
        let mut done_reports: BTreeMap<usize, InstanceReport> = BTreeMap::new();
        let mut all_finished: Vec<FinishedSample> = Vec::new();
        let mut refusals = 0u64;
        let mut ticker = ReallocTicker::new(self.cfg.realloc.period_secs);

        if expected == 0 {
            return Ok(assemble_report(
                Vec::new(),
                BTreeMap::new(),
                0.0,
                0,
                0,
                self.realloc.decisions,
                0.0,
            ));
        }

        loop {
            // Dispatch every arrival that is due, stamping submission at
            // dispatch time. Least-loaded under the memory budget first;
            // when the whole fleet is at budget, still least-loaded (the
            // worker's waiting queue is the backlog).
            let now = t0.elapsed().as_secs_f64();
            while let Some(&(due, _)) = queue.front() {
                if due > now {
                    break;
                }
                let (_, mut task) = queue.pop_front().expect("front was Some");
                // Stamp the *scheduled* arrival instant, not the dispatch
                // instant: if the monitor dispatches late (busy pumping
                // events under load), that lag is real client-visible
                // queueing delay and must stay in the TTFT/queue metrics
                // — matching the sim plane, which anchors latency at the
                // arrival-event time.
                task.submitted_at = Some(t0 + Duration::from_secs_f64(due));
                let dest = (0..n_inst)
                    .filter(|&i| counts[i] < cap)
                    .min_by_key(|&i| counts[i])
                    .or_else(|| (0..n_inst).min_by_key(|&i| counts[i]))
                    .expect("service always has at least one worker");
                counts[dest] += 1; // optimistic; refreshed by Progress
                let _ = self.cmd_txs[dest].send(Cmd::Add(vec![task]));
            }

            // Wake in time for the next arrival; otherwise the generous
            // first-step compile timeout applies (see run_batch).
            let timeout = if let Some(&(due, _)) = queue.front() {
                let wait = due - t0.elapsed().as_secs_f64();
                Duration::from_secs_f64(wait.clamp(0.001, 900.0))
            } else {
                Duration::from_secs(900)
            };
            let ev = match self.ev_rx.recv_timeout(timeout) {
                Ok(e) => e,
                Err(_) if !queue.is_empty() => continue, // arrival due
                Err(_) => {
                    return Err(anyhow!(
                        "streaming generation stalled: {} / {expected} finished after {:?}",
                        finished_counts.iter().sum::<usize>(),
                        t0.elapsed()
                    ))
                }
            };
            let Some(ev) = self.relay_protocol_event(ev, &mut refusals) else {
                continue;
            };
            match ev {
                Event::Progress {
                    instance,
                    sample_count,
                    throughput,
                    finished,
                } => {
                    counts[instance] = sample_count;
                    finished_counts[instance] = finished;
                    step += 1;
                    self.realloc.observe(sample_count.max(1), throughput);
                    // Occupancy is time-varying here: while the fleet is
                    // saturated, arrivals (not migrations) fill deficits.
                    let saturated = counts.iter().all(|&c| c >= cap);
                    self.realloc.note_backlog(saturated as usize);

                    let due = if ticker.timed() {
                        ticker.due(t0.elapsed().as_secs_f64())
                            && self.realloc.inefficiency(&counts)
                    } else {
                        self.realloc.should_decide(step, &counts)
                    };
                    if self.cfg.realloc.enabled && !reported && due {
                        let sw = Instant::now();
                        self.realloc.refit_threshold();
                        migrations += self.dispatch_plan(step, &counts, &caps);
                        srd_secs += sw.elapsed().as_secs_f64();
                    }

                    if !reported
                        && queue.is_empty()
                        && finished_counts.iter().sum::<usize>() >= expected
                    {
                        reported = true;
                        for tx in &self.cmd_txs {
                            let _ = tx.send(Cmd::Report);
                        }
                    }
                }
                other => {
                    if Self::absorb_done(other, &mut all_finished, &mut done_reports, n_inst)? {
                        break;
                    }
                }
            }
        }
        self.realloc.note_backlog(0);

        Ok(assemble_report(
            all_finished,
            done_reports,
            t0.elapsed().as_secs_f64(),
            migrations,
            refusals,
            self.realloc.decisions,
            srd_secs,
        ))
    }

    /// Stop all workers and join.
    pub fn shutdown(self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        for j in self.joins {
            let _ = j.join();
        }
    }
}

/// One-shot convenience wrapper (start → run_batch → shutdown).
pub fn run_generation(
    artifacts_dir: &std::path::Path,
    cfg: &RunConfig,
    mode: DecodeMode,
    tasks: Vec<SampleTask>,
    target_weights: &[HostTensor],
    draft_weights: &[HostTensor],
) -> Result<GenerationReport> {
    let mut svc =
        GenerationService::start(artifacts_dir, cfg, mode, target_weights, draft_weights)?;
    let report = svc.run_batch(tasks)?;
    svc.shutdown();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(wall_secs: f64, tokens: u64, finished: usize) -> GenerationReport {
        GenerationReport {
            finished: (0..finished)
                .map(|i| FinishedSample {
                    id: i as u64,
                    prompt: vec![1],
                    response: vec![2],
                    rounds: 1,
                    drafts_accepted: 0,
                    drafts_proposed: 0,
                    latency: None,
                })
                .collect(),
            instances: Vec::new(),
            wall_secs,
            migrations: 0,
            migration_refusals: 0,
            realloc_decisions: 0,
            srd_secs: 0.0,
            total_tokens: tokens,
            latency: LatencySummary::default(),
        }
    }

    #[test]
    fn throughput_guards_zero_elapsed() {
        let r = report(0.0, 100, 4);
        assert_eq!(r.throughput_tokens(), 0.0);
        assert_eq!(r.throughput_samples(), 0.0);
        let neg = report(-1.0, 100, 4);
        assert_eq!(neg.throughput_tokens(), 0.0);
    }

    #[test]
    fn throughput_normal_case() {
        let r = report(2.0, 100, 4);
        assert!((r.throughput_tokens() - 50.0).abs() < 1e-9);
        assert!((r.throughput_samples() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn start_rejects_faulty_transport_on_the_real_plane() {
        // The `[transport]` section is honored by the sim plane; the
        // threaded driver's channels are reliable, so a fault schedule
        // there must error loudly instead of silently doing nothing.
        // (Checked before artifact loading, so this needs no PJRT.)
        let mut cfg = RunConfig::default();
        cfg.set("transport.stage2.drop_prob", "0.5").unwrap();
        let err = GenerationService::start(
            std::path::Path::new("/nonexistent"),
            &cfg,
            DecodeMode::Ar,
            &[],
            &[],
        )
        .err()
        .expect("faulty transport must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains("transport"), "{msg}");
    }

    #[test]
    fn realloc_ticker_fires_on_period_grid() {
        let mut t = ReallocTicker::new(0.5);
        assert!(t.timed());
        assert!(!t.due(0.0));
        assert!(!t.due(0.49));
        assert!(t.due(0.5), "first tick at one period");
        assert!(!t.due(0.6), "tick consumed until the next period");
        assert!(t.due(1.01));
    }

    #[test]
    fn realloc_ticker_collapses_missed_periods() {
        // A monitor that slept through several periods (one long decode
        // step) gets exactly one catch-up tick, re-anchored on the grid.
        let mut t = ReallocTicker::new(0.25);
        assert!(t.due(1.6), "first poll after 6+ periods fires once");
        assert!(!t.due(1.7), "missed periods are not replayed");
        assert!(t.due(1.75), "next grid point still fires");
    }

    #[test]
    fn realloc_ticker_disabled_by_nonpositive_period() {
        for p in [0.0, -1.0, f64::NAN] {
            let mut t = ReallocTicker::new(p);
            assert!(!t.timed());
            assert!(!t.due(1e9));
        }
    }
}
