//! Multi-instance generation driver (Fig 6 workflow).
//!
//! One worker thread per generation instance (each owns its PJRT client —
//! the "one client per GPU" topology), a monitor loop in the caller's
//! thread, and message-passing for the reallocation/migration protocol:
//!
//! ```text
//!   monitor                worker s                worker d
//!     │  MigrateOut(s→d,k)   │                        │
//!     ├──────────────────────▶ pick victims           │
//!     │        AllocReq      │                        │
//!     ◀──────────────────────┤                        │
//!     ├──── DeliverAllocReq ─────────────────────────▶ capacity check
//!     │        AllocAck      │                        │
//!     ◀───────────────────────────────────────────────┤
//!     ├──── AllocAck(ok) ────▶ send Stage1 (bulk KV)  │
//!     │        Stage1        │   …keeps decoding…     │
//!     ◀──────────────────────┤                        │
//!     ├──── DeliverStage1 ───────────────────────────▶ unpack (phase 3)
//!     │        Stage2        │ (next step boundary)   │
//!     ◀──────────────────────┤ delta + control        │
//!     ├──── DeliverStage2 ───────────────────────────▶ resume samples
//! ```
//!
//! Initial allocation is sequential round-robin (paper §4: "training
//! samples are first sequentially allocated to the generation instances").

use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::instance::{
    DecodeMode, FinishedSample, GenerationInstance, LiveSample, SampleTask,
};
use crate::coordinator::metrics::InstanceMetrics;
use crate::coordinator::migration::{
    migration_score, pack_hierarchical, unpack_hierarchical, AllocRequest, HierarchicalKv,
    SampleControl,
};
use crate::coordinator::reallocator::Reallocator;
use crate::runtime::{HostTensor, Manifest, ModelStore};
use crate::spec::kvcache::KvCache;
use crate::utils::stats::Ema;

// ---------------------------------------------------------------------------
// Protocol messages
// ---------------------------------------------------------------------------

enum Cmd {
    Add(Vec<SampleTask>),
    MigrateOut { to: usize, count: usize },
    AllocAck { ok: bool },
    DeliverAllocReq(AllocRequest),
    DeliverStage1(Stage1Pkt),
    DeliverStage2(Stage2Pkt),
    /// Broadcast fresh actor/draft weights (next RLHF iteration).
    UpdateWeights(Vec<HostTensor>, Vec<HostTensor>),
    /// Emit a Done report for the current batch but keep running.
    Report,
    Stop,
}

struct Stage1Pkt {
    from: usize,
    kv: HierarchicalKv,
}

struct Stage2Pkt {
    from: usize,
    kv_delta: HierarchicalKv,
    control: Vec<SampleControl>,
    waiting_tasks: Vec<SampleTask>,
}

enum Event {
    Progress {
        instance: usize,
        sample_count: usize,
        throughput: f64,
        finished: usize,
    },
    AllocReq {
        to: usize,
        req: AllocRequest,
    },
    AllocAck {
        to_source: usize,
        ok: bool,
    },
    Stage1 {
        to: usize,
        pkt: Stage1Pkt,
    },
    Stage2 {
        to: usize,
        pkt: Stage2Pkt,
    },
    MigrationRefused,
    Done {
        instance: usize,
        finished: Vec<FinishedSample>,
        metrics: Box<InstanceMetrics>,
        fig7_curve: Vec<(f64, f64, u64)>,
        accept_corr: f64,
        tsd_cache_hits: u64,
        tsd_cache_misses: u64,
    },
    Fatal {
        instance: usize,
        error: String,
    },
}

/// Per-instance summary returned to the caller.
pub struct InstanceReport {
    pub id: usize,
    pub metrics: InstanceMetrics,
    pub fig7_curve: Vec<(f64, f64, u64)>,
    pub accept_corr: f64,
    pub tsd_cache_hits: u64,
    pub tsd_cache_misses: u64,
}

/// Whole-run summary.
pub struct GenerationReport {
    pub finished: Vec<FinishedSample>,
    pub instances: Vec<InstanceReport>,
    pub wall_secs: f64,
    pub migrations: u64,
    pub migration_refusals: u64,
    pub realloc_decisions: u64,
    /// Seconds the monitor spent inside reallocation decisions (§7.7 SRD).
    pub srd_secs: f64,
    /// Total generated tokens across instances.
    pub total_tokens: u64,
}

impl GenerationReport {
    pub fn throughput_tokens(&self) -> f64 {
        self.total_tokens as f64 / self.wall_secs.max(1e-9)
    }

    pub fn throughput_samples(&self) -> f64 {
        self.finished.len() as f64 / self.wall_secs.max(1e-9)
    }
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

struct MigOutState {
    to: usize,
    live_ids: Vec<u64>,
    snapshots: Vec<usize>,
    waiting_tasks: Vec<SampleTask>,
    stage1_sent: bool,
}

struct Worker {
    inst: GenerationInstance,
    cmds: Receiver<Cmd>,
    events: Sender<Event>,
    mig_out: Option<MigOutState>,
    /// Stage-1 buffers keyed by source instance: (draft,target) caches + ids.
    mig_in_kv: BTreeMap<usize, (Vec<(KvCache, KvCache)>, Vec<u64>)>,
    throughput: Ema,
    last_tokens: u64,
}

impl Worker {
    fn run(mut self) {
        loop {
            // Drain commands.
            loop {
                match self.cmds.try_recv() {
                    Ok(Cmd::Stop) => {
                        self.finishup();
                        return;
                    }
                    Ok(cmd) => {
                        if let Err(e) = self.handle(cmd) {
                            let _ = self.events.send(Event::Fatal {
                                instance: self.inst.id,
                                error: format!("{e:#}"),
                            });
                            return;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        self.finishup();
                        return;
                    }
                }
            }

            if self.inst.is_idle() {
                // Flush any Stage-2 that was waiting on a step boundary
                // (all victims may have finished during the overlap step).
                if let Some(state) = self.mig_out.take() {
                    if state.stage1_sent {
                        if self.send_stage2(state).is_err() {
                            return;
                        }
                    } else {
                        self.mig_out = Some(state);
                    }
                }
                // Nothing to do: block briefly for commands.
                match self.cmds.recv_timeout(Duration::from_millis(5)) {
                    Ok(Cmd::Stop) => {
                        self.finishup();
                        return;
                    }
                    Ok(cmd) => {
                        if let Err(e) = self.handle(cmd) {
                            let _ = self.events.send(Event::Fatal {
                                instance: self.inst.id,
                                error: format!("{e:#}"),
                            });
                            return;
                        }
                    }
                    Err(_) => {}
                }
                continue;
            }

            let t0 = Instant::now();
            if let Err(e) = self.inst.step() {
                let _ = self.events.send(Event::Fatal {
                    instance: self.inst.id,
                    error: format!("{e:#}"),
                });
                return;
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let new_tokens = self.inst.metrics.tokens_out - self.last_tokens;
            self.last_tokens = self.inst.metrics.tokens_out;
            let tp = self.throughput.update(new_tokens as f64 / dt);

            // Stage 2 of an in-flight outbound migration fires at the step
            // boundary after Stage 1 (the overlapped decode step).
            if let Some(state) = self.mig_out.take() {
                if state.stage1_sent {
                    if let Err(e) = self.send_stage2(state) {
                        let _ = self.events.send(Event::Fatal {
                            instance: self.inst.id,
                            error: format!("{e:#}"),
                        });
                        return;
                    }
                } else {
                    self.mig_out = Some(state);
                }
            }

            let _ = self.events.send(Event::Progress {
                instance: self.inst.id,
                sample_count: self.inst.sample_count(),
                throughput: tp,
                finished: self.inst.finished.len(),
            });
        }
    }

    fn handle(&mut self, cmd: Cmd) -> Result<()> {
        match cmd {
            Cmd::Add(tasks) => {
                for t in tasks {
                    self.inst.add_task(t);
                }
            }
            Cmd::MigrateOut { to, count } => self.begin_migration(to, count)?,
            Cmd::AllocAck { ok } => self.on_alloc_ack(ok)?,
            Cmd::DeliverAllocReq(req) => {
                // Capacity check: accept if total samples stay within 4×
                // decode slots (the instance's practical memory budget).
                let cap = self.inst.capacity() * 4;
                let ok = self.inst.sample_count() + req.sample_ids.len() <= cap;
                let _ = self.events.send(Event::AllocAck {
                    to_source: req.from_instance,
                    ok,
                });
            }
            Cmd::DeliverStage1(pkt) => {
                // Phase 3: unpack into fresh per-sample caches immediately.
                let man = self.inst.engine.manifest.clone();
                let n = pkt.kv.spans.len();
                let mut caches: Vec<(KvCache, KvCache)> = (0..n)
                    .map(|_| {
                        (
                            KvCache::new(
                                man.draft.n_layers,
                                man.draft.n_heads,
                                man.draft.max_seq,
                                man.draft.d_head,
                            ),
                            KvCache::new(
                                man.target.n_layers,
                                man.target.n_heads,
                                man.target.max_seq,
                                man.target.d_head,
                            ),
                        )
                    })
                    .collect();
                {
                    let mut drafts: Vec<&mut KvCache> = Vec::new();
                    let mut targets: Vec<&mut KvCache> = Vec::new();
                    for (d, t) in caches.iter_mut() {
                        drafts.push(d);
                        targets.push(t);
                    }
                    unpack_hierarchical(&pkt.kv, &mut drafts, &mut targets);
                }
                let ids = pkt.kv.spans.iter().map(|s| s.id).collect();
                self.mig_in_kv.insert(pkt.from, (caches, ids));
            }
            Cmd::DeliverStage2(pkt) => self.finish_migration_in(pkt)?,
            Cmd::UpdateWeights(tw, dw) => {
                self.inst.target.set_weights(&tw)?;
                self.inst.draft.set_weights(&dw)?;
            }
            Cmd::Report => self.report_batch(),
            Cmd::Stop => unreachable!("handled by caller"),
        }
        Ok(())
    }

    /// Emit a Done event for the finished-so-far batch without stopping.
    fn report_batch(&mut self) {
        let fig7_curve = self.inst.accept_pred.curve();
        let accept_corr = self.inst.accept_pred.correlation();
        let _ = self.events.send(Event::Done {
            instance: self.inst.id,
            finished: std::mem::take(&mut self.inst.finished),
            metrics: Box::new(self.inst.metrics.clone()),
            fig7_curve,
            accept_corr,
            tsd_cache_hits: self.inst.tsd_pred.cache_hits,
            tsd_cache_misses: self.inst.tsd_pred.cache_misses,
        });
    }

    /// Source side: pick victims and send the alloc request.
    fn begin_migration(&mut self, to: usize, count: usize) -> Result<()> {
        let mut remaining = count;
        // Waiting tasks first: no KV to move at all.
        let mut waiting_tasks = Vec::new();
        while remaining > 0 && !self.inst.waiting.is_empty() {
            waiting_tasks.push(self.inst.waiting.pop().unwrap());
            remaining -= 1;
        }
        // Then parked, treated like waiting but carrying KV — simplest is
        // to treat them as live victims below; push them back to live pick.
        // Live victims by the §6.1 score: short sequences, low accept rate.
        let max_seq = self.inst.engine.manifest.target.max_seq;
        let mut scored: Vec<(f64, u64)> = self
            .inst
            .live
            .iter()
            .chain(self.inst.parked.iter())
            .map(|s| {
                (
                    migration_score(s.seq_len(), s.mean_accepted(), max_seq),
                    s.task.id,
                )
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        // Never migrate ALL live samples away (keep at least one decoding
        // unless the order insists).
        let live_ids: Vec<u64> = scored.iter().take(remaining).map(|&(_, id)| id).collect();

        if waiting_tasks.is_empty() && live_ids.is_empty() {
            let _ = self.events.send(Event::MigrationRefused);
            return Ok(());
        }
        if live_ids.is_empty() {
            // Only queue transfers: no KV, no handshake needed — a single
            // Stage-2 message carries the tasks.
            self.inst.metrics.samples_migrated_out += waiting_tasks.len() as u64;
            let empty = pack_hierarchical(&[], &[], &[], &[]);
            let _ = self.events.send(Event::Stage2 {
                to,
                pkt: Stage2Pkt {
                    from: self.inst.id,
                    kv_delta: empty,
                    control: Vec::new(),
                    waiting_tasks,
                },
            });
            return Ok(());
        }
        let snapshots: Vec<usize> = live_ids
            .iter()
            .map(|id| self.find_sample(*id).map(|s| s.prefix_len).unwrap_or(0))
            .collect();
        let bytes: usize = live_ids
            .iter()
            .zip(&snapshots)
            .map(|(id, &snap)| {
                self.find_sample(*id)
                    .map(|s| {
                        2 * snap * (s.target_cache.row_elems() + s.draft_cache.row_elems()) * 4
                    })
                    .unwrap_or(0)
            })
            .sum();
        let req = AllocRequest {
            from_instance: self.inst.id,
            sample_ids: live_ids.clone(),
            bytes,
        };
        self.mig_out = Some(MigOutState {
            to,
            live_ids,
            snapshots,
            waiting_tasks,
            stage1_sent: false,
        });
        let _ = self.events.send(Event::AllocReq { to, req });
        Ok(())
    }

    fn find_sample(&self, id: u64) -> Option<&LiveSample> {
        self.inst
            .live
            .iter()
            .chain(self.inst.parked.iter())
            .find(|s| s.task.id == id)
    }

    fn on_alloc_ack(&mut self, ok: bool) -> Result<()> {
        let Some(mut state) = self.mig_out.take() else {
            return Ok(());
        };
        if !ok {
            // §6.2 phase 2: clear buffers, give waiting tasks back, report.
            self.inst.waiting.extend(state.waiting_tasks.drain(..));
            let _ = self.events.send(Event::MigrationRefused);
            return Ok(());
        }
        // Stage 1: pack the snapshot of verified KV; samples KEEP decoding.
        let mut drafts = Vec::new();
        let mut targets = Vec::new();
        let mut ids = Vec::new();
        let mut ranges = Vec::new();
        for (id, &snap) in state.live_ids.iter().zip(&state.snapshots) {
            if let Some(s) = self.find_sample(*id) {
                drafts.push(&s.draft_cache);
                targets.push(&s.target_cache);
                ids.push(*id);
                ranges.push((0usize, snap));
            }
        }
        let kv = pack_hierarchical(&drafts, &targets, &ids, &ranges);
        let _ = self.events.send(Event::Stage1 {
            to: state.to,
            pkt: Stage1Pkt { from: self.inst.id, kv },
        });
        state.stage1_sent = true;
        self.inst.metrics.samples_migrated_out += state.live_ids.len() as u64;
        self.mig_out = Some(state);
        Ok(())
    }

    /// Source side, one step after Stage 1: the delta + control state.
    fn send_stage2(&mut self, state: MigOutState) -> Result<()> {
        // Keep (victim, snapshot) pairs aligned even if some victims
        // finished during the overlapped step (they stay on the source).
        let mut victims: Vec<(LiveSample, usize)> = Vec::new();
        for (id, &snap) in state.live_ids.iter().zip(&state.snapshots) {
            if let Some(s) = self
                .inst
                .take_live(*id)
                .or_else(|| {
                    self.inst
                        .parked
                        .iter()
                        .position(|p| p.task.id == *id)
                        .map(|i| self.inst.parked.remove(i))
                })
            {
                victims.push((s, snap));
            }
        }
        let mut drafts = Vec::new();
        let mut targets = Vec::new();
        let mut ids = Vec::new();
        let mut ranges = Vec::new();
        let mut control = Vec::new();
        for (v, snap) in victims.iter() {
            drafts.push(&v.draft_cache);
            targets.push(&v.target_cache);
            ids.push(v.task.id);
            ranges.push((*snap, v.prefix_len));
            control.push(SampleControl::from_live(v));
        }
        let kv_delta = pack_hierarchical(&drafts, &targets, &ids, &ranges);
        let _ = self.events.send(Event::Stage2 {
            to: state.to,
            pkt: Stage2Pkt {
                from: self.inst.id,
                kv_delta,
                control,
                waiting_tasks: state.waiting_tasks,
            },
        });
        Ok(())
    }

    /// Destination side: merge the delta, rebuild live samples, resume.
    fn finish_migration_in(&mut self, pkt: Stage2Pkt) -> Result<()> {
        self.inst.metrics.samples_migrated_in += pkt.waiting_tasks.len() as u64;
        for t in pkt.waiting_tasks {
            self.inst.add_task(t);
        }
        let (mut caches, ids) = self.mig_in_kv.remove(&pkt.from).unwrap_or_default();
        // Merge the delta into the stage-1 caches (ids must align).
        if !pkt.kv_delta.spans.is_empty() {
            let mut drafts: Vec<&mut KvCache> = Vec::new();
            let mut targets: Vec<&mut KvCache> = Vec::new();
            for span in &pkt.kv_delta.spans {
                let pos = ids
                    .iter()
                    .position(|id| id == &span.id)
                    .ok_or_else(|| anyhow!("stage2 delta for unknown sample {}", span.id))?;
                // Safety: spans have unique ids, so disjoint indices.
                let ptr = caches.as_mut_ptr();
                unsafe {
                    drafts.push(&mut (*ptr.add(pos)).0);
                    targets.push(&mut (*ptr.add(pos)).1);
                }
            }
            unpack_hierarchical(&pkt.kv_delta, &mut drafts, &mut targets);
        }
        for ctl in pkt.control {
            let pos = ids
                .iter()
                .position(|id| *id == ctl.task.id)
                .ok_or_else(|| anyhow!("stage2 control for unknown sample {}", ctl.task.id))?;
            let (draft_cache, target_cache) = {
                let c = &caches[pos];
                (c.0.clone(), c.1.clone())
            };
            let live = LiveSample {
                task: ctl.task,
                generated: ctl.generated,
                prefix_len: ctl.prefix_len,
                target_cache,
                draft_cache,
                rounds: ctl.rounds,
                drafts_accepted: ctl.drafts_accepted,
                drafts_proposed: ctl.drafts_proposed,
            };
            self.inst.insert_parked(live);
        }
        Ok(())
    }

    fn finishup(mut self) {
        let fig7_curve = self.inst.accept_pred.curve();
        let accept_corr = self.inst.accept_pred.correlation();
        let _ = self.events.send(Event::Done {
            instance: self.inst.id,
            finished: std::mem::take(&mut self.inst.finished),
            metrics: Box::new(self.inst.metrics.clone()),
            fig7_curve,
            accept_corr,
            tsd_cache_hits: self.inst.tsd_pred.cache_hits,
            tsd_cache_misses: self.inst.tsd_pred.cache_misses,
        });
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Persistent multi-instance generation service.
///
/// Worker threads (each with its own PJRT client and compiled executables)
/// live across RLHF iterations: [`GenerationService::run_batch`] processes
/// one generation stage, [`GenerationService::update_weights`] broadcasts
/// the freshly trained actor/draft weights, and compiled artifacts are
/// reused — exactly how a serving fleet amortizes warmup.
pub struct GenerationService {
    cfg: RunConfig,
    manifest: Manifest,
    cmd_txs: Vec<Sender<Cmd>>,
    ev_rx: Receiver<Event>,
    joins: Vec<std::thread::JoinHandle<()>>,
    realloc: Reallocator,
    mode: DecodeMode,
}

impl GenerationService {
    /// Spawn workers. `weights` cross the thread boundary as host tensors
    /// (`xla::Literal` is not Send); each worker materializes its stores.
    pub fn start(
        artifacts_dir: &std::path::Path,
        cfg: &RunConfig,
        mode: DecodeMode,
        target_weights: &[HostTensor],
        draft_weights: &[HostTensor],
    ) -> Result<GenerationService> {
        let n_inst = cfg.rlhf.instances.max(1);
        let manifest = Manifest::load(artifacts_dir)?;
        let (ev_tx, ev_rx) = channel::<Event>();
        let mut cmd_txs: Vec<Sender<Cmd>> = Vec::new();
        let mut joins = Vec::new();

        for i in 0..n_inst {
            let (tx, rx) = channel::<Cmd>();
            cmd_txs.push(tx);
            let ev = ev_tx.clone();
            let man = manifest.clone();
            let cfgc = cfg.clone();
            let tw: Vec<HostTensor> = target_weights.to_vec();
            let dw: Vec<HostTensor> = draft_weights.to_vec();
            let seed = cfg.seed ^ (0xABCD + i as u64);
            joins.push(std::thread::spawn(move || {
                let man = Rc::new(man);
                let mut target = match ModelStore::init(&man, "target", 0) {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = ev.send(Event::Fatal { instance: i, error: format!("{e:#}") });
                        return;
                    }
                };
                let mut draft = ModelStore::init(&man, "draft", 0).unwrap();
                if target.set_weights(&tw).is_err() || draft.set_weights(&dw).is_err() {
                    let _ = ev.send(Event::Fatal {
                        instance: i,
                        error: "weight broadcast failed".into(),
                    });
                    return;
                }
                let inst =
                    match GenerationInstance::new(i, man, target, draft, cfgc, mode, seed) {
                        Ok(x) => x,
                        Err(e) => {
                            let _ = ev
                                .send(Event::Fatal { instance: i, error: format!("{e:#}") });
                            return;
                        }
                    };
                Worker {
                    inst,
                    cmds: rx,
                    events: ev,
                    mig_out: None,
                    mig_in_kv: BTreeMap::new(),
                    throughput: Ema::new(0.3),
                    last_tokens: 0,
                }
                .run();
            }));
        }
        Ok(GenerationService {
            cfg: cfg.clone(),
            manifest,
            cmd_txs,
            ev_rx,
            joins,
            realloc: Reallocator::new(cfg.realloc.threshold, cfg.realloc.cooldown as u64),
            mode,
        })
    }

    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    /// Broadcast fresh actor/draft weights to every worker.
    pub fn update_weights(
        &self,
        target_weights: &[HostTensor],
        draft_weights: &[HostTensor],
    ) -> Result<()> {
        for tx in &self.cmd_txs {
            tx.send(Cmd::UpdateWeights(
                target_weights.to_vec(),
                draft_weights.to_vec(),
            ))
            .map_err(|_| anyhow!("worker channel closed"))?;
        }
        Ok(())
    }

    /// Process one batch of samples to completion (one generation stage).
    pub fn run_batch(&mut self, tasks: Vec<SampleTask>) -> Result<GenerationReport> {
        let n_inst = self.cmd_txs.len();
        let expected = tasks.len();
        // Drain stale events from a previous batch.
        while self.ev_rx.try_recv().is_ok() {}

        // Sequential initial allocation (§4).
        let mut batches: Vec<Vec<SampleTask>> = vec![Vec::new(); n_inst];
        for (i, t) in tasks.into_iter().enumerate() {
            batches[i % n_inst].push(t);
        }
        for (i, b) in batches.into_iter().enumerate() {
            let _ = self.cmd_txs[i].send(Cmd::Add(b));
        }

        let t0 = Instant::now();
        let mut counts = vec![0usize; n_inst];
        let mut finished_counts = vec![0usize; n_inst];
        let mut step: u64 = 0;
        let mut migrations = 0u64;
        let mut srd_secs = 0.0f64;
        let mut reported = false;
        let mut done_reports: BTreeMap<usize, InstanceReport> = BTreeMap::new();
        let mut all_finished: Vec<FinishedSample> = Vec::new();
        let mut refusals = 0u64;

        loop {
            // Generous stall timeout: a worker's FIRST step lazily
            // compiles several XLA executables, which can take minutes on
            // a small shared-CPU box.
            let ev = match self.ev_rx.recv_timeout(Duration::from_secs(900)) {
                Ok(e) => e,
                Err(_) => {
                    return Err(anyhow!(
                        "generation stalled: {} / {expected} finished after {:?}",
                        finished_counts.iter().sum::<usize>(),
                        t0.elapsed()
                    ))
                }
            };
            match ev {
                Event::Progress {
                    instance,
                    sample_count,
                    throughput,
                    finished,
                } => {
                    counts[instance] = sample_count;
                    finished_counts[instance] = finished;
                    step += 1;
                    self.realloc.observe(sample_count.max(1), throughput);

                    if self.cfg.realloc.enabled
                        && !reported
                        && self.realloc.should_decide(step, &counts)
                    {
                        let sw = Instant::now();
                        self.realloc.refit_threshold();
                        let caps: Vec<usize> = vec![
                            self.manifest
                                .batch_buckets
                                .iter()
                                .max()
                                .copied()
                                .unwrap_or(1)
                                * 4;
                            n_inst
                        ];
                        let plan = self.realloc.decide(step, &counts, &caps);
                        srd_secs += sw.elapsed().as_secs_f64();
                        for m in plan {
                            migrations += 1;
                            let _ = self.cmd_txs[m.from].send(Cmd::MigrateOut {
                                to: m.to,
                                count: m.count,
                            });
                        }
                    }

                    if !reported && finished_counts.iter().sum::<usize>() >= expected {
                        reported = true;
                        for tx in &self.cmd_txs {
                            let _ = tx.send(Cmd::Report);
                        }
                    }
                }
                Event::AllocReq { to, req } => {
                    let _ = self.cmd_txs[to].send(Cmd::DeliverAllocReq(req));
                }
                Event::AllocAck { to_source, ok } => {
                    let _ = self.cmd_txs[to_source].send(Cmd::AllocAck { ok });
                }
                Event::Stage1 { to, pkt } => {
                    let _ = self.cmd_txs[to].send(Cmd::DeliverStage1(pkt));
                }
                Event::Stage2 { to, pkt } => {
                    let _ = self.cmd_txs[to].send(Cmd::DeliverStage2(pkt));
                }
                Event::MigrationRefused => {
                    refusals += 1;
                    self.realloc.report_refusal();
                }
                Event::Done {
                    instance,
                    finished,
                    metrics,
                    fig7_curve,
                    accept_corr,
                    tsd_cache_hits,
                    tsd_cache_misses,
                } => {
                    all_finished.extend(finished);
                    done_reports.insert(
                        instance,
                        InstanceReport {
                            id: instance,
                            metrics: *metrics,
                            fig7_curve,
                            accept_corr,
                            tsd_cache_hits,
                            tsd_cache_misses,
                        },
                    );
                    if done_reports.len() == n_inst {
                        break;
                    }
                }
                Event::Fatal { instance, error } => {
                    return Err(anyhow!("instance {instance} failed: {error}"));
                }
            }
        }

        let total_tokens = done_reports.values().map(|r| r.metrics.tokens_out).sum();
        Ok(GenerationReport {
            finished: all_finished,
            instances: done_reports.into_values().collect(),
            wall_secs: t0.elapsed().as_secs_f64(),
            migrations,
            migration_refusals: refusals,
            realloc_decisions: self.realloc.decisions,
            srd_secs,
            total_tokens,
        })
    }

    /// Stop all workers and join.
    pub fn shutdown(self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        for j in self.joins {
            let _ = j.join();
        }
    }
}

/// One-shot convenience wrapper (start → run_batch → shutdown).
pub fn run_generation(
    artifacts_dir: &std::path::Path,
    cfg: &RunConfig,
    mode: DecodeMode,
    tasks: Vec<SampleTask>,
    target_weights: &[HostTensor],
    draft_weights: &[HostTensor],
) -> Result<GenerationReport> {
    let mut svc =
        GenerationService::start(artifacts_dir, cfg, mode, target_weights, draft_weights)?;
    let report = svc.run_batch(tasks)?;
    svc.shutdown();
    Ok(report)
}
