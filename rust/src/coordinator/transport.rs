//! Transport abstraction for the §6.2 migration protocol.
//!
//! Every protocol message — `AllocReq → AllocAck → Stage1 → Stage2` plus
//! the Stage-2 acknowledgement that confirms an order — crosses a
//! [`Transport`]. A transport does not *carry* payloads (the carriers —
//! the threaded driver's channels and the virtual cluster's event heap —
//! own delivery); it *plans* each send: how many copies arrive and with
//! how much extra delay. That keeps the fault model in one place and the
//! carriers oblivious to it:
//!
//! * [`PerfectTransport`] — every message delivered exactly once with no
//!   extra delay. This is today's behavior: carriers detect it via
//!   [`Transport::is_perfect`] and take their zero-overhead synchronous
//!   paths, so fault-free runs stay bit-identical to the pre-transport
//!   code.
//! * [`crate::sim::link::FaultyLink`] — seeded, schedulable faults on the
//!   virtual link: per-[`MsgClass`] drop/duplicate/reorder probabilities
//!   and bounded extra delay, drawn from a salted deterministic RNG
//!   stream so any fault schedule replays bit-for-bit.
//!
//! The endpoint ([`crate::coordinator::core::InstanceCore`]) is hardened
//! against whatever a transport does: per-order sequence numbers,
//! idempotent Stage-1/Stage-2 apply (dedup on the order id), and — on the
//! source — a limbo buffer that holds shipped victims until the order is
//! confirmed, so retransmits cannot lose, duplicate, or double-count a
//! sample. See `docs/ARCHITECTURE.md` ("Transport & fault plane").

use anyhow::{bail, Result};

/// The §6.2 protocol message classes a transport can fault independently.
///
/// Acknowledgements (`AllocAck`, the Stage-1 bulk ack and the Stage-2
/// confirmation) share the [`MsgClass::AllocAck`] fault profile: all are
/// small control replies riding the same reverse path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgClass {
    /// §6.2 phase-2 allocation request (source → destination).
    AllocReq,
    /// Allocation reply, Stage-1 bulk acknowledgement
    /// ([`TransportConfig::stage1_ack`]) and the Stage-2 confirmation
    /// (destination → source).
    AllocAck,
    /// Stage-1 bulk KV snapshot (source → destination).
    Stage1,
    /// Stage-2 delta + control state — the commit message (source →
    /// destination).
    Stage2,
}

/// Fault probabilities of one message class.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultProfile {
    /// Probability a message copy is silently lost.
    pub drop_prob: f64,
    /// Probability an extra duplicate copy is delivered (with its own
    /// random extra delay, so duplicates also reorder).
    pub dup_prob: f64,
    /// Probability the (surviving) copy is delayed by a uniform draw in
    /// `[0, extra_delay_secs]` — at non-zero delay this reorders it
    /// against later traffic.
    pub reorder_prob: f64,
    /// Upper bound of the injected extra delay, in link seconds.
    pub extra_delay_secs: f64,
}

impl FaultProfile {
    /// A profile that never faults (all probabilities zero).
    pub fn perfect() -> Self {
        FaultProfile::default()
    }

    /// True when this profile can never drop, duplicate, or delay.
    pub fn is_perfect(&self) -> bool {
        self.drop_prob <= 0.0 && self.dup_prob <= 0.0 && self.reorder_prob <= 0.0
    }

    /// Uniform profile: the same drop/dup/reorder probabilities with a
    /// delay bound.
    pub fn uniform(drop: f64, dup: f64, reorder: f64, extra_delay_secs: f64) -> Self {
        FaultProfile { drop_prob: drop, dup_prob: dup, reorder_prob: reorder, extra_delay_secs }
    }
}

/// The `[transport]` configuration section: per-class fault profiles plus
/// the reliability knobs of the hardened endpoint protocol.
///
/// Reliability layer semantics (implemented by the carriers):
///
/// * while an order is in its *handshake* phase (AllocReq sent, no ack
///   yet) the source retransmits every [`TransportConfig::retransmit_secs`]
///   up to [`TransportConfig::retransmit_budget`] times; exceeding the
///   budget — or the hard [`TransportConfig::handshake_timeout_secs`]
///   deadline — **aborts** the order and returns its victims to the
///   source batch (nothing has left the source yet);
/// * once Stage 1/Stage 2 are in flight the order is *committed*:
///   retransmission is unbounded (the victims sit in the source's limbo
///   buffer until the destination's confirmation arrives), because an
///   abort after the commit point could duplicate samples.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    /// Fault profile of [`MsgClass::AllocReq`] messages.
    pub alloc_req: FaultProfile,
    /// Fault profile of [`MsgClass::AllocAck`] messages (allocation
    /// replies *and* Stage-2 confirmations).
    pub alloc_ack: FaultProfile,
    /// Fault profile of [`MsgClass::Stage1`] messages.
    pub stage1: FaultProfile,
    /// Fault profile of [`MsgClass::Stage2`] messages.
    pub stage2: FaultProfile,
    /// Retransmission timer (seconds on the carrier's clock).
    pub retransmit_secs: f64,
    /// Handshake retransmissions before the order aborts.
    pub retransmit_budget: usize,
    /// Hard wall for the handshake phase: if no allocation reply arrived
    /// this many seconds after the first AllocReq, the order aborts even
    /// with retransmit budget left.
    pub handshake_timeout_secs: f64,
    /// Acknowledge the Stage-1 bulk (dest → source, riding the
    /// [`MsgClass::AllocAck`] profile): on the ack, the source stops
    /// retransmitting the bulk and releases its held copy early (only
    /// the small Stage-2 delta stays the source's responsibility —
    /// `InstanceCore::release_bulk`), shrinking both retransmit traffic
    /// and the limbo memory window. Only engages on unreliable links —
    /// the perfect transport has no acks at all, so today's limbo
    /// accounting is untouched (golden-guarded). Default on; set
    /// `transport.stage1_ack = false` for the PR-4 wire behavior.
    pub stage1_ack: bool,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            alloc_req: FaultProfile::perfect(),
            alloc_ack: FaultProfile::perfect(),
            stage1: FaultProfile::perfect(),
            stage2: FaultProfile::perfect(),
            retransmit_secs: 0.02,
            retransmit_budget: 5,
            handshake_timeout_secs: 0.25,
            stage1_ack: true,
        }
    }
}

impl TransportConfig {
    /// True when every class profile is fault-free — carriers then take
    /// their synchronous zero-overhead paths (today's behavior).
    pub fn is_perfect(&self) -> bool {
        self.alloc_req.is_perfect()
            && self.alloc_ack.is_perfect()
            && self.stage1.is_perfect()
            && self.stage2.is_perfect()
    }

    /// The same fault profile on every message class.
    pub fn uniform(profile: FaultProfile) -> Self {
        TransportConfig {
            alloc_req: profile,
            alloc_ack: profile,
            stage1: profile,
            stage2: profile,
            ..TransportConfig::default()
        }
    }

    /// The fault profile of one message class.
    pub fn profile(&self, class: MsgClass) -> FaultProfile {
        match class {
            MsgClass::AllocReq => self.alloc_req,
            MsgClass::AllocAck => self.alloc_ack,
            MsgClass::Stage1 => self.stage1,
            MsgClass::Stage2 => self.stage2,
        }
    }

    /// Set one `[transport]` config key (the part after `transport.`).
    ///
    /// Bare keys (`drop_prob`, `dup_prob`, `reorder_prob`,
    /// `extra_delay_secs`) apply to **all four** classes; class-scoped
    /// keys (`stage2.drop_prob`, `alloc_ack.dup_prob`, …) target one.
    /// `retransmit_secs`, `retransmit_budget`, `handshake_timeout_secs`
    /// and `stage1_ack` set the reliability knobs.
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let f = |v: &str| -> Result<f64> {
            v.parse()
                .map_err(|_| anyhow::anyhow!("expected float, got {v:?}"))
        };
        let u = |v: &str| -> Result<usize> {
            v.parse()
                .map_err(|_| anyhow::anyhow!("expected int, got {v:?}"))
        };
        match key {
            "retransmit_secs" => self.retransmit_secs = f(val)?,
            "retransmit_budget" => self.retransmit_budget = u(val)?,
            "handshake_timeout_secs" => self.handshake_timeout_secs = f(val)?,
            "stage1_ack" => {
                self.stage1_ack = val
                    .parse()
                    .map_err(|_| anyhow::anyhow!("expected bool, got {val:?}"))?
            }
            "drop_prob" => {
                let x = f(val)?;
                self.set_all(|p| p.drop_prob = x);
            }
            "dup_prob" => {
                let x = f(val)?;
                self.set_all(|p| p.dup_prob = x);
            }
            "reorder_prob" => {
                let x = f(val)?;
                self.set_all(|p| p.reorder_prob = x);
            }
            "extra_delay_secs" => {
                let x = f(val)?;
                self.set_all(|p| p.extra_delay_secs = x);
            }
            _ => {
                let Some((class, field)) = key.split_once('.') else {
                    bail!("unknown transport key {key:?}");
                };
                let p = match class {
                    "alloc_req" => &mut self.alloc_req,
                    "alloc_ack" => &mut self.alloc_ack,
                    "stage1" => &mut self.stage1,
                    "stage2" => &mut self.stage2,
                    _ => bail!("unknown transport message class {class:?}"),
                };
                match field {
                    "drop_prob" => p.drop_prob = f(val)?,
                    "dup_prob" => p.dup_prob = f(val)?,
                    "reorder_prob" => p.reorder_prob = f(val)?,
                    "extra_delay_secs" => p.extra_delay_secs = f(val)?,
                    _ => bail!("unknown transport profile field {field:?}"),
                }
            }
        }
        Ok(())
    }

    fn set_all(&mut self, mut set: impl FnMut(&mut FaultProfile)) {
        set(&mut self.alloc_req);
        set(&mut self.alloc_ack);
        set(&mut self.stage1);
        set(&mut self.stage2);
    }
}

/// A transport plans each protocol message's fate; the carrier (driver
/// channels, sim event heap) executes the plan.
pub trait Transport {
    /// Plan one message send: each returned entry is one copy that will
    /// arrive, with that copy's *extra* delay (added on top of the
    /// carrier's base transfer time). An empty plan means the message is
    /// lost; more than one entry means it was duplicated.
    fn plan(&mut self, class: MsgClass, from: usize, to: usize) -> Vec<f64>;

    /// True when every plan is exactly `[0.0]` — carriers may then skip
    /// the event-driven reliability layer entirely.
    fn is_perfect(&self) -> bool;

    /// `(dropped, duplicated)` message counts injected so far.
    fn stats(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// The fault-free transport: every message delivered exactly once,
/// immediately. Draws no randomness, so runs carried over it are
/// bit-identical to the pre-transport code.
#[derive(Clone, Copy, Debug, Default)]
pub struct PerfectTransport;

impl Transport for PerfectTransport {
    fn plan(&mut self, _class: MsgClass, _from: usize, _to: usize) -> Vec<f64> {
        vec![0.0]
    }

    fn is_perfect(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_perfect() {
        let cfg = TransportConfig::default();
        assert!(cfg.is_perfect());
        assert!(cfg.profile(MsgClass::Stage2).is_perfect());
        assert!(cfg.retransmit_budget > 0);
        assert!(cfg.handshake_timeout_secs > cfg.retransmit_secs);
    }

    #[test]
    fn perfect_transport_plans_single_immediate_delivery() {
        let mut t = PerfectTransport;
        assert!(t.is_perfect());
        for class in [MsgClass::AllocReq, MsgClass::AllocAck, MsgClass::Stage1, MsgClass::Stage2] {
            assert_eq!(t.plan(class, 0, 1), vec![0.0]);
        }
        assert_eq!(t.stats(), (0, 0));
    }

    #[test]
    fn uniform_keys_hit_every_class() {
        let mut cfg = TransportConfig::default();
        cfg.set("drop_prob", "0.25").unwrap();
        cfg.set("extra_delay_secs", "0.01").unwrap();
        for class in [MsgClass::AllocReq, MsgClass::AllocAck, MsgClass::Stage1, MsgClass::Stage2] {
            assert_eq!(cfg.profile(class).drop_prob, 0.25);
            assert_eq!(cfg.profile(class).extra_delay_secs, 0.01);
        }
        assert!(!cfg.is_perfect());
    }

    #[test]
    fn class_scoped_keys_hit_one_class() {
        let mut cfg = TransportConfig::default();
        cfg.set("stage2.drop_prob", "0.5").unwrap();
        cfg.set("alloc_ack.dup_prob", "0.125").unwrap();
        assert_eq!(cfg.stage2.drop_prob, 0.5);
        assert_eq!(cfg.alloc_ack.dup_prob, 0.125);
        assert_eq!(cfg.alloc_req.drop_prob, 0.0);
        assert!(cfg.stage1.is_perfect());
    }

    #[test]
    fn reliability_knobs_parse() {
        let mut cfg = TransportConfig::default();
        cfg.set("retransmit_secs", "0.05").unwrap();
        cfg.set("retransmit_budget", "9").unwrap();
        cfg.set("handshake_timeout_secs", "1.5").unwrap();
        cfg.set("stage1_ack", "false").unwrap();
        assert_eq!(cfg.retransmit_secs, 0.05);
        assert_eq!(cfg.retransmit_budget, 9);
        assert_eq!(cfg.handshake_timeout_secs, 1.5);
        assert!(!cfg.stage1_ack);
        // The ack is a reliability knob, not a fault: the config stays
        // perfect either way.
        assert!(cfg.is_perfect());
        assert!(TransportConfig::default().stage1_ack, "ack on by default");
        assert!(cfg.set("stage1_ack", "maybe").is_err());
    }

    #[test]
    fn bad_keys_rejected() {
        let mut cfg = TransportConfig::default();
        assert!(cfg.set("nope", "1").is_err());
        assert!(cfg.set("stage3.drop_prob", "1").is_err());
        assert!(cfg.set("stage2.nope", "1").is_err());
        assert!(cfg.set("drop_prob", "abc").is_err());
    }

    #[test]
    fn uniform_constructor_sets_all_classes() {
        let p = FaultProfile::uniform(0.1, 0.2, 0.3, 0.004);
        let cfg = TransportConfig::uniform(p);
        assert_eq!(cfg.alloc_req, p);
        assert_eq!(cfg.stage2, p);
        assert!(!cfg.is_perfect());
    }
}
