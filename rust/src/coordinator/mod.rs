//! The RLHFSpec coordinator (the paper's L3 contribution).
//!
//! The control plane is implemented **once** and runs on two backends:
//!
//! * [`backend`] — the [`backend::DecodeBackend`] trait: the few
//!   genuinely backend-specific operations (prefill, draft, verify, KV
//!   extract/inject, step cost/clock).
//! * [`core`] — [`core::InstanceCore`]: the adaptive decode loop
//!   (admission, AR vs. speculative stepping, §5.2 weight prediction,
//!   §5.3 budget selection, retirement, metrics) and the §6.2 two-stage
//!   migration endpoint state machine, generic over the backend. The
//!   PJRT plane ([`instance`]) and the virtual-clock plane
//!   ([`crate::sim::engine`]) are both `InstanceCore<_>` instantiations,
//!   so every scheduler change is exercised at cluster scale in ordinary
//!   `cargo test`.
//!
//! Around that core:
//!
//! * [`predictor`] — decision-feature prediction (§5.2): the draft-logit →
//!   acceptance-probability fit `F`, the `t_sd(N_seq, N_draft)` regression,
//!   and the bucket-based prediction cache.
//! * [`selector`] — workload-aware drafting-strategy selection (§5.3):
//!   layer-level incremental search with sugar-water-inequality pruning.
//! * [`policy`] — the pluggable drafting control plane above the
//!   selector (`[policy]` config section): the `DraftPolicy` trait with
//!   the bit-inert static default, a contextual-UCB bandit learning
//!   per-step from acceptance feedback (with forgetting at RLHF
//!   weight-update barriers), and the skip-layer self-speculative mode.
//! * [`reallocator`] — sample-reallocation policy (§6.1): roofline
//!   threshold, greedy source/destination pairing under the Eq-6
//!   constraints, cooldown.
//! * [`federation`] — the sharded control plane's cross-shard layer:
//!   per-shard load digests exchanged on the reallocation cadence and
//!   the greedy digest-pairing planner that emits at most one
//!   cross-shard migration order per shard per round (`[shard]` config
//!   section; K = 1 keeps the single fleet-global coordinator).
//! * [`migration`] — two-stage KV migration payloads (§6.2): hierarchical
//!   packing, allocation handshake types, compute/transfer overlap.
//! * [`transport`] — the message-transport abstraction under the §6.2
//!   protocol: per-class fault profiles (`[transport]` config section),
//!   the perfect transport (today's behavior), and the reliability knobs
//!   (retransmit timer/budget, handshake timeout) the hardened endpoint
//!   honors. The unreliable implementation lives in [`crate::sim::link`].
//! * [`instance`] — the PJRT backend: the speculative round phases
//!   (draft → verify → accept → commit) over compiled executables.
//! * [`driver`] — multi-instance generation: worker threads, initial
//!   allocation, the monitor/reallocation loop pumping the shared
//!   endpoint protocol.
//! * [`metrics`] — per-stage timing and counters (§7.7 overhead
//!   analysis) plus the serving-latency summaries (TTFT/TPOT/queueing
//!   delay) both planes report for streaming workloads.
//!
//! See `docs/ARCHITECTURE.md` for the full paper-section → module map
//! and the event-flow diagrams.

// Every public item in the coordinator must be documented; CI runs
// `cargo doc --no-deps` with `RUSTDOCFLAGS="-D warnings"` to enforce it.
#![warn(missing_docs)]

pub mod backend;
pub mod core;
pub mod driver;
pub mod federation;
pub mod instance;
pub mod metrics;
pub mod migration;
pub mod policy;
pub mod predictor;
pub mod reallocator;
pub mod selector;
pub mod transport;
