//! The RLHFSpec coordinator (the paper's L3 contribution).
//!
//! * [`predictor`] — decision-feature prediction (§5.2): the draft-logit →
//!   acceptance-probability fit `F`, the `t_sd(N_seq, N_draft)` regression,
//!   and the bucket-based prediction cache.
//! * [`selector`] — workload-aware drafting-strategy selection (§5.3):
//!   layer-level incremental search with sugar-water-inequality pruning.
//! * [`reallocator`] — sample-reallocation policy (§6.1): roofline
//!   threshold, greedy source/destination pairing under the Eq-6
//!   constraints, cooldown.
//! * [`migration`] — two-stage KV migration (§6.2): hierarchical packing,
//!   allocation handshake, compute/transfer overlap.
//! * [`instance`] — a generation instance: the speculative round loop
//!   (draft → select → verify → accept → commit) over PJRT executables.
//! * [`driver`] — multi-instance generation: worker threads, initial
//!   allocation, the monitor/reallocation loop.
//! * [`metrics`] — per-stage timing and counters (§7.7 overhead analysis).

pub mod driver;
pub mod instance;
pub mod metrics;
pub mod migration;
pub mod predictor;
pub mod reallocator;
pub mod selector;
