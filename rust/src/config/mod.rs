//! Run configuration: generation, selector, reallocation and RLHF knobs.
//!
//! Values load from a simple `key = value` config file (TOML-subset with
//! `[section]` headers, comments, strings, numbers, bools) and can be
//! overridden from CLI `--section.key value` options, so every example and
//! bench shares one config surface.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::policy::PolicyConfig;
use crate::coordinator::transport::TransportConfig;
use crate::sim::crash::CrashConfig;
use crate::sim::rlhf_loop::RlhfLoopConfig;
use crate::sim::trace::{default_trace_config, TraceConfig};

/// Speculative generation knobs (paper §2.2, §5).
#[derive(Clone, Debug)]
pub struct SpecConfig {
    /// Children expanded per tree node during drafting.
    pub branch: usize,
    /// Maximum tree depth (draft steps per speculative round).
    pub max_depth: usize,
    /// Maximum draft token budget n considered by the selector.
    pub max_draft: usize,
    /// Fixed n for the static-`Speculative` baseline.
    pub static_n: usize,
    /// Sampling temperature for generation.
    pub temperature: f32,
    /// Greedy (argmax) acceptance vs stochastic speculative sampling.
    pub greedy: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { branch: 2, max_depth: 5, max_draft: 16, static_n: 8, temperature: 1.0, greedy: false }
    }
}

/// Workload-aware drafting-strategy selector knobs (paper §5).
#[derive(Clone, Debug)]
pub struct SelectorConfig {
    /// Enable the selector (off = static_n baseline behaviour).
    pub enabled: bool,
    /// Early-stop after this many consecutive objective decreases (§5.3).
    pub patience: usize,
    /// Bucket widths for the t_sd prediction cache (§5.2).
    pub nseq_bucket: usize,
    pub ndraft_bucket: usize,
    /// Online refit period (steps) for the acceptance/t_sd models.
    pub refit_every: usize,
    /// Also refit (and drop the t_sd bucket cache) whenever batch
    /// occupancy changes between steps, rate-limited to once per 8 steps.
    /// Off by default — batch-synchronous runs see occupancy change only
    /// at the drain tail, and the fixed cadence is calibrated for them.
    /// Streaming workloads (continuous batching) should enable this so
    /// the §5.3 budget search re-evaluates as occupancy ramps instead of
    /// waiting out the `refit_every` cadence at a stale operating point.
    pub refit_on_occupancy_change: bool,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig {
            enabled: true,
            patience: 2,
            nseq_bucket: 256,
            ndraft_bucket: 4,
            refit_every: 64,
            refit_on_occupancy_change: false,
        }
    }
}

/// Sample-reallocation knobs (paper §6).
#[derive(Clone, Debug)]
pub struct ReallocConfig {
    pub enabled: bool,
    /// Decision period in steps (§6.1 "cooldown").
    pub cooldown: usize,
    /// Initial throughput-roofline threshold (samples); refined online.
    pub threshold: usize,
    /// Simulated interconnect bandwidth for KV transfer (bytes/sec).
    pub link_bandwidth: f64,
    /// Simulated per-message link latency (seconds).
    pub link_latency: f64,
    /// Wall-clock decision cadence for the threaded driver, in seconds.
    /// `> 0` replaces the step-counter cooldown with timed ticks (the
    /// meaningful schedule when instances step at different rates);
    /// `<= 0` (default) keeps the step cadence.
    pub period_secs: f64,
    /// Batched multi-destination orders: one decision may split a
    /// source's surplus across several destinations (and fill one deep
    /// deficit from several sources). Requires nothing extra — the
    /// hardened endpoint runs the handshakes concurrently — but is off
    /// by default to keep the paper's `m(k) <= 1` pairing.
    pub multi_dest: bool,
}

impl Default for ReallocConfig {
    fn default() -> Self {
        ReallocConfig {
            enabled: true,
            cooldown: 8,
            threshold: 8,
            // PCIe 4.0 x16-ish effective bandwidth, per the paper's testbed.
            link_bandwidth: 20e9,
            link_latency: 20e-6,
            period_secs: 0.0,
            multi_dest: false,
        }
    }
}

/// RLHF pipeline knobs (paper §2.1).
#[derive(Clone, Debug)]
pub struct RlhfConfig {
    pub instances: usize,
    pub samples_per_iter: usize,
    pub max_new_tokens: usize,
    pub prompt_len: usize,
    pub lr: f32,
    pub clip_eps: f32,
    pub kl_coef: f32,
    pub ent_coef: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
}

impl Default for RlhfConfig {
    fn default() -> Self {
        RlhfConfig {
            instances: 2,
            samples_per_iter: 16,
            max_new_tokens: 48,
            prompt_len: 16,
            lr: 1e-4,
            clip_eps: 0.2,
            kl_coef: 0.02,
            ent_coef: 0.0,
            gamma: 1.0,
            gae_lambda: 0.95,
        }
    }
}

/// Discrete-event engine knobs (`[engine]` section).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads for the cluster simulator's event loop. `1` (the
    /// default) runs the sequential loop unchanged; `> 1` enables the
    /// conservative-time-window parallel engine, which is bit-identical
    /// to the sequential loop at any thread count (see
    /// `docs/ARCHITECTURE.md` § Parallel engine). When unset in the
    /// config file, the `PALLAS_ENGINE_THREADS` environment variable
    /// provides the default — that is how the CI thread-matrix leg runs
    /// every existing suite under the parallel engine without touching
    /// each test.
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { threads: default_engine_threads() }
    }
}

/// Sharded-control-plane knobs (`[shard]` section).
///
/// `count = 1` (the default) keeps the single fleet-global coordinator
/// and is bit-inert: admission stays the least-loaded scan, no admission
/// RNG stream is created, and no federation round runs. `count > 1`
/// partitions the fleet into contiguous shards, each with its own
/// admission queue, refusal ledger and `Reallocator`, switches the
/// arrival fast path to power-of-two-choices sampling on the
/// `seed ^ ADMIT_SEED_SALT` stream, and runs the
/// [`federation`](crate::coordinator::federation) digest exchange on the
/// reallocation cadence. Cross-shard migration orders travel the same
/// simulated links, degraded by the two factor knobs below.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Coordinator shard count K; clamped to `1 ..= instances`.
    pub count: usize,
    /// Multiplier on link latency when source and destination live in
    /// different shards (inter-shard hops cross a slower fabric).
    /// Clamped to ≥ 1 (never *better* than the intra-shard link).
    pub link_latency_factor: f64,
    /// Divisor on link bandwidth for cross-shard transfers; clamped to
    /// ≥ 1 likewise.
    pub link_bandwidth_factor: f64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig { count: 1, link_latency_factor: 4.0, link_bandwidth_factor: 4.0 }
    }
}

impl ShardConfig {
    /// Set one `[shard]` key (already stripped of the section prefix).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let u = |v: &str| -> Result<usize> {
            v.parse().map_err(|_| anyhow!("expected int, got {v:?}"))
        };
        let f64_ = |v: &str| -> Result<f64> {
            v.parse().map_err(|_| anyhow!("expected float, got {v:?}"))
        };
        match key {
            "count" => self.count = u(val)?.max(1),
            "link_latency_factor" => self.link_latency_factor = f64_(val)?,
            "link_bandwidth_factor" => self.link_bandwidth_factor = f64_(val)?,
            _ => bail!("unknown config key"),
        }
        Ok(())
    }

    /// The latency multiplier with the ≥ 1 / finite clamp applied.
    pub fn latency_factor(&self) -> f64 {
        if self.link_latency_factor.is_finite() { self.link_latency_factor.max(1.0) } else { 1.0 }
    }

    /// The bandwidth divisor with the ≥ 1 / finite clamp applied.
    pub fn bandwidth_factor(&self) -> f64 {
        if self.link_bandwidth_factor.is_finite() {
            self.link_bandwidth_factor.max(1.0)
        } else {
            1.0
        }
    }
}

/// Engine thread count from `PALLAS_ENGINE_THREADS`, clamped to ≥ 1;
/// `1` (the sequential loop) when unset or unparseable.
pub fn default_engine_threads() -> usize {
    std::env::var("PALLAS_ENGINE_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(1)
}

/// Top-level run config.
#[derive(Clone, Debug, Default)]
pub struct RunConfig {
    pub spec: SpecConfig,
    pub selector: SelectorConfig,
    pub realloc: ReallocConfig,
    pub rlhf: RlhfConfig,
    /// `[transport]` — §6.2 message-transport fault model + reliability
    /// knobs (see [`TransportConfig`]). Fault-free by default. Honored
    /// by the simulated link *and* (since the driver-channel fault port)
    /// the threaded driver's monitor relay, which injects the same
    /// per-class drop/duplicate schedules into its command channels.
    pub transport: TransportConfig,
    /// `[crash]` — whole-instance crash/recovery fault model (see
    /// [`CrashConfig`]). Crash-free by default. Honored by the simulated
    /// cluster; the threaded driver cannot kill its own worker threads,
    /// so `GenerationService::start` *rejects* a non-zero section
    /// instead of silently ignoring it.
    pub crash: CrashConfig,
    /// `[engine]` — event-engine execution knobs (worker threads).
    pub engine: EngineConfig,
    /// `[shard]` — sharded coordinator control plane (see
    /// [`ShardConfig`]). `count = 1` by default: one fleet-global
    /// coordinator, bit-identical to the pre-shard engine.
    pub shard: ShardConfig,
    /// `[rlhf_sim]` — event-driven multi-iteration RLHF loop on the
    /// simulated cluster (see [`RlhfLoopConfig`]). `iters = 0` by
    /// default: the loop plane never arms and every run is bit-identical
    /// to a plain generation run.
    pub rlhf_sim: RlhfLoopConfig,
    /// `[trace]` — structured trace & metrics plane (see
    /// [`TraceConfig`]). Disabled by default: with tracing off the
    /// cluster constructs no sink and replays bit-for-bit. The
    /// `PALLAS_TRACE` env var overrides the *default*; an explicit
    /// `[trace]` section or `--trace.*` override still wins.
    pub trace: TraceConfig,
    /// `[policy]` — the drafting control plane (see [`PolicyConfig`]).
    /// `kind = "static"` by default: every decision delegates to the §5
    /// selector and runs are bit-identical to the pre-policy scheduler.
    pub policy: PolicyConfig,
    pub seed: u64,
}

impl RunConfig {
    /// Load from a TOML-subset file then apply CLI-style overrides.
    pub fn load(path: Option<&Path>, overrides: &BTreeMap<String, String>) -> Result<RunConfig> {
        let mut kv = BTreeMap::new();
        if let Some(p) = path {
            let src = std::fs::read_to_string(p)
                .with_context(|| format!("reading config {p:?}"))?;
            parse_toml_subset(&src, &mut kv)?;
        }
        for (k, v) in overrides {
            kv.insert(k.clone(), v.clone());
        }
        // `PALLAS_TRACE` seeds the *default* trace config; explicit
        // `[trace]` keys (file or CLI) below still override it.
        let mut cfg = RunConfig { trace: default_trace_config(), ..RunConfig::default() };
        for (k, v) in &kv {
            cfg.set(k, v).with_context(|| format!("config key {k:?}"))?;
        }
        Ok(cfg)
    }

    /// Set one dotted key, e.g. `spec.max_depth = 6`.
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let b = |v: &str| -> Result<bool> {
            v.parse().map_err(|_| anyhow!("expected bool, got {v:?}"))
        };
        let u = |v: &str| -> Result<usize> {
            v.parse().map_err(|_| anyhow!("expected int, got {v:?}"))
        };
        let f = |v: &str| -> Result<f32> {
            v.parse().map_err(|_| anyhow!("expected float, got {v:?}"))
        };
        let f64_ = |v: &str| -> Result<f64> {
            v.parse().map_err(|_| anyhow!("expected float, got {v:?}"))
        };
        match key {
            "seed" => self.seed = u(val)? as u64,
            "spec.branch" => self.spec.branch = u(val)?,
            "spec.max_depth" => self.spec.max_depth = u(val)?,
            "spec.max_draft" => self.spec.max_draft = u(val)?,
            "spec.static_n" => self.spec.static_n = u(val)?,
            "spec.temperature" => self.spec.temperature = f(val)?,
            "spec.greedy" => self.spec.greedy = b(val)?,
            "selector.enabled" => self.selector.enabled = b(val)?,
            "selector.patience" => self.selector.patience = u(val)?,
            "selector.nseq_bucket" => self.selector.nseq_bucket = u(val)?,
            "selector.ndraft_bucket" => self.selector.ndraft_bucket = u(val)?,
            "selector.refit_every" => self.selector.refit_every = u(val)?,
            "selector.refit_on_occupancy_change" => {
                self.selector.refit_on_occupancy_change = b(val)?
            }
            "realloc.enabled" => self.realloc.enabled = b(val)?,
            "realloc.cooldown" => self.realloc.cooldown = u(val)?,
            "realloc.threshold" => self.realloc.threshold = u(val)?,
            "realloc.link_bandwidth" => self.realloc.link_bandwidth = f64_(val)?,
            "realloc.link_latency" => self.realloc.link_latency = f64_(val)?,
            "realloc.period_secs" => self.realloc.period_secs = f64_(val)?,
            "realloc.multi_dest" => self.realloc.multi_dest = b(val)?,
            "rlhf.instances" => self.rlhf.instances = u(val)?,
            "rlhf.samples_per_iter" => self.rlhf.samples_per_iter = u(val)?,
            "rlhf.max_new_tokens" => self.rlhf.max_new_tokens = u(val)?,
            "rlhf.prompt_len" => self.rlhf.prompt_len = u(val)?,
            "rlhf.lr" => self.rlhf.lr = f(val)?,
            "rlhf.clip_eps" => self.rlhf.clip_eps = f(val)?,
            "rlhf.kl_coef" => self.rlhf.kl_coef = f(val)?,
            "rlhf.ent_coef" => self.rlhf.ent_coef = f(val)?,
            "rlhf.gamma" => self.rlhf.gamma = f(val)?,
            "rlhf.gae_lambda" => self.rlhf.gae_lambda = f(val)?,
            "engine.threads" => self.engine.threads = u(val)?.max(1),
            _ => {
                // `[transport]` / `[crash]` keys are parsed by their own
                // config types — one config surface for both planes
                // (the driver rejects a non-zero `[crash]` section at
                // start; crash injection is simulation-only).
                if let Some(rest) = key.strip_prefix("transport.") {
                    return self.transport.set(rest, val);
                }
                if let Some(rest) = key.strip_prefix("crash.") {
                    return self.crash.set(rest, val);
                }
                if let Some(rest) = key.strip_prefix("shard.") {
                    return self.shard.set(rest, val);
                }
                if let Some(rest) = key.strip_prefix("rlhf_sim.") {
                    return self.rlhf_sim.set(rest, val);
                }
                if let Some(rest) = key.strip_prefix("trace.") {
                    return self.trace.set(rest, val);
                }
                if let Some(rest) = key.strip_prefix("policy.") {
                    return self.policy.set(rest, val);
                }
                bail!("unknown config key")
            }
        }
        Ok(())
    }
}

/// Parse `[section]` + `key = value` lines into dotted keys.
fn parse_toml_subset(src: &str, out: &mut BTreeMap<String, String>) -> Result<()> {
    let mut section = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = v.trim().trim_matches('"').to_string();
        out.insert(key, val);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::default();
        assert!(c.spec.max_draft >= c.spec.branch);
        assert!(c.selector.enabled);
        assert!(c.realloc.link_bandwidth > 1e9);
    }

    #[test]
    fn toml_subset_parses() {
        let src = r#"
            seed = 7
            [spec]
            max_depth = 6   # comment
            greedy = true
            [rlhf]
            lr = 0.001
        "#;
        let mut kv = BTreeMap::new();
        parse_toml_subset(src, &mut kv).unwrap();
        let cfg = RunConfig::load(None, &kv).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.spec.max_depth, 6);
        assert!(cfg.spec.greedy);
        assert!((cfg.rlhf.lr - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = RunConfig::default();
        assert!(cfg.set("nope.nope", "1").is_err());
    }

    #[test]
    fn transport_section_parses() {
        let src = r#"
            [transport]
            drop_prob = 0.1          # all four classes
            stage2.dup_prob = 0.25   # one class
            retransmit_budget = 7
            handshake_timeout_secs = 0.5
            [realloc]
            period_secs = 0.5
            multi_dest = true
        "#;
        let mut kv = BTreeMap::new();
        parse_toml_subset(src, &mut kv).unwrap();
        let cfg = RunConfig::load(None, &kv).unwrap();
        assert!(!cfg.transport.is_perfect());
        assert_eq!(cfg.transport.alloc_req.drop_prob, 0.1);
        assert_eq!(cfg.transport.stage2.drop_prob, 0.1);
        assert_eq!(cfg.transport.stage2.dup_prob, 0.25);
        assert_eq!(cfg.transport.alloc_ack.dup_prob, 0.0);
        assert_eq!(cfg.transport.retransmit_budget, 7);
        assert_eq!(cfg.transport.handshake_timeout_secs, 0.5);
        assert_eq!(cfg.realloc.period_secs, 0.5);
        assert!(cfg.realloc.multi_dest);
        // Defaults stay fault-free (today's behavior).
        assert!(RunConfig::default().transport.is_perfect());
        assert_eq!(RunConfig::default().realloc.period_secs, 0.0);
    }

    #[test]
    fn crash_section_parses() {
        let src = r#"
            [crash]
            rate_per_sec = 0.1
            recover_secs = 2.5
            max_crashes = 12
            [transport]
            stage1_ack = false
        "#;
        let mut kv = BTreeMap::new();
        parse_toml_subset(src, &mut kv).unwrap();
        let cfg = RunConfig::load(None, &kv).unwrap();
        assert!(!cfg.crash.is_off());
        assert_eq!(cfg.crash.rate_per_sec, 0.1);
        assert_eq!(cfg.crash.recover_secs, 2.5);
        assert_eq!(cfg.crash.max_crashes, 12);
        assert!(!cfg.transport.stage1_ack);
        // Defaults stay crash-free (today's behavior).
        assert!(RunConfig::default().crash.is_off());
        let mut bad = RunConfig::default();
        assert!(bad.set("crash.nope", "1").is_err());
        assert!(bad.set("crash.rate_per_sec", "abc").is_err());
    }

    #[test]
    fn engine_section_parses_and_clamps() {
        let src = r#"
            [engine]
            threads = 4
        "#;
        let mut kv = BTreeMap::new();
        parse_toml_subset(src, &mut kv).unwrap();
        let cfg = RunConfig::load(None, &kv).unwrap();
        assert_eq!(cfg.engine.threads, 4);
        // 0 would mean "no workers" — clamp to the sequential loop.
        let mut c = RunConfig::default();
        c.set("engine.threads", "0").unwrap();
        assert_eq!(c.engine.threads, 1);
        assert!(c.set("engine.threads", "abc").is_err());
        assert!(c.set("engine.nope", "1").is_err());
    }

    #[test]
    fn shard_section_parses_and_clamps() {
        let src = r#"
            [shard]
            count = 8
            link_latency_factor = 6.0
            link_bandwidth_factor = 2.0
        "#;
        let mut kv = BTreeMap::new();
        parse_toml_subset(src, &mut kv).unwrap();
        let cfg = RunConfig::load(None, &kv).unwrap();
        assert_eq!(cfg.shard.count, 8);
        assert_eq!(cfg.shard.latency_factor(), 6.0);
        assert_eq!(cfg.shard.bandwidth_factor(), 2.0);
        // Defaults keep the single fleet-global coordinator.
        assert_eq!(RunConfig::default().shard.count, 1);
        let mut c = RunConfig::default();
        c.set("shard.count", "0").unwrap(); // clamp, not error
        assert_eq!(c.shard.count, 1);
        // Sub-1 factors would make cross-shard links *better* — clamped.
        c.set("shard.link_latency_factor", "0.25").unwrap();
        assert_eq!(c.shard.latency_factor(), 1.0);
        assert!(c.set("shard.count", "abc").is_err());
        assert!(c.set("shard.nope", "1").is_err());
    }

    #[test]
    fn rlhf_sim_section_parses() {
        use crate::sim::rlhf_loop::{LoopMode, Placement};
        let src = r#"
            [rlhf_sim]
            iters = 4
            samples_per_iter = 32
            mode = "async"
            placement = "disaggregated"
            train_instances = 2
            train_tier = "h100"
            staleness_bound = 1
            accept_decay = 0.9
            refresh_every = 2
            refresh_secs = 0.5
        "#;
        let mut kv = BTreeMap::new();
        parse_toml_subset(src, &mut kv).unwrap();
        let cfg = RunConfig::load(None, &kv).unwrap();
        assert!(!cfg.rlhf_sim.is_off());
        assert_eq!(cfg.rlhf_sim.iters, 4);
        assert_eq!(cfg.rlhf_sim.samples_per_iter, 32);
        assert_eq!(cfg.rlhf_sim.mode, LoopMode::Async);
        assert_eq!(cfg.rlhf_sim.placement, Placement::Disaggregated);
        assert_eq!(cfg.rlhf_sim.train_instances, 2);
        assert_eq!(cfg.rlhf_sim.train_tier, "h100");
        assert_eq!(cfg.rlhf_sim.staleness_bound, 1);
        assert_eq!(cfg.rlhf_sim.accept_decay, 0.9);
        assert_eq!(cfg.rlhf_sim.refresh_every, 2);
        assert_eq!(cfg.rlhf_sim.refresh_secs, 0.5);
        // Defaults keep the loop plane disarmed (today's behavior).
        assert!(RunConfig::default().rlhf_sim.is_off());
        let mut bad = RunConfig::default();
        assert!(bad.set("rlhf_sim.nope", "1").is_err());
        assert!(bad.set("rlhf_sim.iters", "abc").is_err());
        assert!(bad.set("rlhf_sim.mode", "sideways").is_err());
    }

    #[test]
    fn policy_section_parses() {
        use crate::coordinator::policy::PolicyKind;
        let src = r#"
            [policy]
            kind = "bandit"
            bandit_c = 0.8
            forget = 0.5
            window = 128
            self_draft_frac = 0.25
            self_accept_penalty = 0.9
            selfspec_tiers = "l40s,a100"
        "#;
        let mut kv = BTreeMap::new();
        parse_toml_subset(src, &mut kv).unwrap();
        let cfg = RunConfig::load(None, &kv).unwrap();
        assert_eq!(cfg.policy.kind, PolicyKind::Bandit);
        assert!(!cfg.policy.is_static());
        assert_eq!(cfg.policy.bandit_c, 0.8);
        assert_eq!(cfg.policy.forget, 0.5);
        assert_eq!(cfg.policy.window, 128.0);
        assert_eq!(cfg.policy.self_draft_frac, 0.25);
        assert_eq!(cfg.policy.self_accept_penalty, 0.9);
        assert_eq!(cfg.policy.selfspec_tiers, "l40s,a100");
        // Defaults keep the bit-inert static selector (today's behavior).
        assert!(RunConfig::default().policy.is_static());
        let mut bad = RunConfig::default();
        assert!(bad.set("policy.nope", "1").is_err());
        assert!(bad.set("policy.kind", "sideways").is_err());
        assert!(bad.set("policy.window", "abc").is_err());
    }

    #[test]
    fn bad_transport_key_rejected() {
        let mut cfg = RunConfig::default();
        assert!(cfg.set("transport.nope", "1").is_err());
        assert!(cfg.set("transport.stage2.nope", "1").is_err());
        assert!(cfg.set("transport.drop_prob", "abc").is_err());
    }

    #[test]
    fn bad_value_rejected() {
        let mut cfg = RunConfig::default();
        assert!(cfg.set("spec.max_depth", "abc").is_err());
    }
}
