//! Mini property-testing harness (no proptest in the offline registry).
//!
//! `check(name, cases, |rng| ...)` runs a closure over many seeded RNGs;
//! on failure it reports the exact seed so the case can be replayed with
//! `check_seed`. Coordinator invariants (reallocation constraints, selector
//! optimality, tree connectivity, migration round-trips) are verified with
//! this harness throughout `rust/tests/`.
//!
//! `PALLAS_PROP_CASES` multiplies every property's case count — the PR
//! gate runs the fast default (unset = 1×), and CI's scheduled "deep"
//! job re-runs the suites at 10× to sweep far more fault/crash
//! schedules without slowing down pull requests.

use crate::utils::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 200;

/// Parse a `PALLAS_PROP_CASES` value into a case-count multiplier
/// (unset/invalid/0 = 1). Pure, so it is testable without mutating the
/// process environment (`set_var` races other test threads' `getenv`).
fn parse_case_multiplier(v: Option<&str>) -> usize {
    v.and_then(|s| s.parse::<usize>().ok())
        .filter(|&m| m >= 1)
        .unwrap_or(1)
}

/// Case-count multiplier from `PALLAS_PROP_CASES`.
fn case_multiplier() -> usize {
    parse_case_multiplier(std::env::var("PALLAS_PROP_CASES").ok().as_deref())
}

/// Run `prop` over `cases` seeded RNGs (scaled by `PALLAS_PROP_CASES`);
/// panic with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut prop: F) {
    // Base seed is stable so CI is deterministic; override with
    // RLHFSPEC_PROP_SEED for exploration.
    let base: u64 = std::env::var("RLHFSPEC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let cases = cases.saturating_mul(case_multiplier());
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {case} (seed={seed:#x}): {msg}\n\
                 replay with testutil::check_seed({seed:#x}, ...)"
            );
        }
    }
}

/// Replay a single failing case.
pub fn check_seed<F: FnOnce(&mut Rng)>(seed: u64, prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

/// Convenience: assert two f64 are within tolerance.
pub fn assert_close(a: f64, b: f64, tol: f64) {
    assert!(
        (a - b).abs() <= tol,
        "not close: {a} vs {b} (tol {tol})"
    );
}

/// Convenience: random sorted vector of distinct usizes in [0, hi).
pub fn distinct_sorted(rng: &mut Rng, n: usize, hi: usize) -> Vec<usize> {
    assert!(n <= hi);
    let mut all: Vec<usize> = (0..hi).collect();
    rng.shuffle(&mut all);
    let mut v: Vec<usize> = all.into_iter().take(n).collect();
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("x+0==x", 50, |rng| {
            let x = rng.below(1000);
            assert_eq!(x + 0, x);
        });
    }

    #[test]
    fn prop_cases_multiplier_parses_defensively() {
        // The deep-CI knob: PALLAS_PROP_CASES scales every property's
        // case count. Parsing is pure (no env mutation — set_var would
        // race other test threads); unset/invalid/zero all mean 1×.
        assert_eq!(parse_case_multiplier(None), 1);
        assert_eq!(parse_case_multiplier(Some("")), 1);
        assert_eq!(parse_case_multiplier(Some("abc")), 1);
        assert_eq!(parse_case_multiplier(Some("0")), 1);
        assert_eq!(parse_case_multiplier(Some("1")), 1);
        assert_eq!(parse_case_multiplier(Some("10")), 10);
        // And check() applies the multiplier (1× without the env set —
        // the test harness never exports the knob).
        let mut ran = 0usize;
        check("multiplier-baseline", 10, |_rng| ran += 1);
        assert_eq!(ran, 10 * case_multiplier());
    }

    #[test]
    #[should_panic(expected = "property")]
    fn check_reports_failure_with_seed() {
        check("always-false", 10, |_rng| {
            panic!("intentional");
        });
    }

    #[test]
    fn distinct_sorted_is_distinct_and_sorted() {
        check("distinct_sorted", 50, |rng| {
            let v = distinct_sorted(rng, 10, 50);
            assert_eq!(v.len(), 10);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
        });
    }
}
