//! Spec showdown: AR vs static-n vs workload-aware speculative decoding
//! on the real PJRT path, with a distilled draft — the Fig-13 ablation on
//! real hardware-in-miniature.
//!
//! ```bash
//! cargo run --release --example spec_showdown -- --artifacts artifacts/tiny
//! ```

use std::path::PathBuf;

use rlhfspec::config::RunConfig;
use rlhfspec::coordinator::instance::DecodeMode;
use rlhfspec::rlhf::RlhfPipeline;
use rlhfspec::utils::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts/tiny"));
    let n = args.usize_or("samples", 8);
    let seed = args.u64_or("seed", 5);

    let mut cfg = RunConfig::default();
    cfg.seed = seed;
    cfg.rlhf.instances = 1;
    cfg.rlhf.max_new_tokens = args.usize_or("max-new", 24);
    cfg.spec.greedy = true; // deterministic: all modes emit identical text
    cfg.spec.max_depth = 4;
    cfg.spec.max_draft = 12;

    // One warm-up pipeline provides trained weights for every mode.
    let mut p = RlhfPipeline::new(&dir, cfg.clone(), "gsm8k", seed)?;
    println!("warming up (pretrain + distill)…");
    p.pretrain_actor(args.usize_or("pretrain", 60), 3e-3)?;
    p.distill_draft(args.usize_or("distill", 60), 3e-3)?;

    println!(
        "\n{:<14} {:>9} {:>9} {:>10} {:>9} {:>9}",
        "mode", "wall(s)", "tok/s", "tok/round", "accept%", "speedup"
    );
    let mut baseline = None;
    let mut reference_text: Option<Vec<Vec<i32>>> = None;
    for (label, mode) in [
        ("autoregressive", DecodeMode::Ar),
        ("static n=4", DecodeMode::StaticSpec(4)),
        ("static n=12", DecodeMode::StaticSpec(12)),
        ("adaptive", DecodeMode::Adaptive),
    ] {
        p.start_generation(mode)?;
        // Same seed ⇒ same prompts per mode (tasks drawn from pipeline rng;
        // regenerate the pipeline rng stream by using a fresh pipeline? we
        // instead draw fresh prompts — greedy decoding still lets us check
        // cross-mode consistency on the samples we compare below).
        let report = p.generate_once(n)?;
        p.stop_generation();
        let wall = report.wall_secs;
        let toks = report.total_tokens;
        let rounds: u64 = report.instances.iter().map(|r| r.metrics.rounds).sum();
        let acc: u64 = report.instances.iter().map(|r| r.metrics.drafts_accepted).sum();
        let prop: u64 = report
            .instances
            .iter()
            .map(|r| r.metrics.drafts_proposed)
            .sum();
        let tps = toks as f64 / wall;
        let speedup = match baseline {
            None => {
                baseline = Some(tps);
                1.0
            }
            Some(b) => tps / b,
        };
        println!(
            "{:<14} {:>9.2} {:>9.1} {:>10.2} {:>8.1}% {:>8.2}×",
            label,
            wall,
            tps,
            toks as f64 / rounds.max(1) as f64,
            100.0 * acc as f64 / prop.max(1) as f64,
            speedup
        );
        if reference_text.is_none() {
            reference_text = Some(report.finished.iter().map(|f| f.response.clone()).collect());
        }
    }
    println!("\n(greedy decoding: every mode is token-identical to AR on the same prompt — \
              verified by `generation_integration::greedy_spec_equals_greedy_ar`)");
    Ok(())
}
