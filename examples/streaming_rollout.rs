//! Streaming rollout: drive a Poisson (continuous-batching) workload
//! through the virtual cluster and print the serving-latency
//! percentiles — the workload real RLHF rollout systems face, which the
//! paper's batch-synchronous evaluation cannot show.
//!
//! ```bash
//! cargo run --release --example streaming_rollout            # defaults
//! cargo run --release --example streaming_rollout 12 256     # rate, samples
//! ```
//!
//! See `docs/ARCHITECTURE.md` ("Streaming arrivals and admission") for
//! how the arrival/admission path threads through the event heap.

use rlhfspec::data::arrivals::ArrivalProcess;
use rlhfspec::sim::cluster::{ClusterConfig, FleetTier, SimCluster};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let rate: f64 = args.next().map(|a| a.parse()).transpose()?.unwrap_or(8.0);
    let n_samples: usize = args.next().map(|a| a.parse()).transpose()?.unwrap_or(192);

    // A mixed fleet: two fast tiers plus the L40S baseline, each with its
    // own reallocation knee. Small decode batches make queueing visible.
    let mut cfg = ClusterConfig {
        fleet: vec![
            FleetTier::preset("h100", 2).expect("known preset"),
            FleetTier::preset("a100", 2).expect("known preset"),
            FleetTier::preset("l40s", 4).expect("known preset"),
        ],
        n_samples,
        max_tokens: 512,
        cooldown: 24,
        seed: 0,
        ..Default::default()
    };
    cfg.params.max_batch = 8;
    // Occupancy ramps as arrivals land: let the §5 selector refit on
    // batch-occupancy changes instead of a fixed step cadence.
    cfg.params.selector.refit_on_occupancy_change = true;

    println!("offering {n_samples} samples at {rate}/s to a 2×h100 + 2×a100 + 4×l40s fleet…");
    let mut cluster = SimCluster::streaming(cfg, &ArrivalProcess::poisson(rate))?;
    let r = cluster.run();

    println!(
        "\ncompleted {}/{} samples in {:.1} virtual s ({} refused at admission)",
        r.n_samples, r.arrivals, r.makespan, r.admission_refusals
    );
    println!(
        "throughput: {:.0} tok/s, {:.2} samples/s | {} migrations, {} realloc decisions",
        r.tokens_per_sec(),
        r.samples_per_sec(),
        r.migrations,
        r.realloc_decisions
    );
    println!("\nserving latency over {} samples:", r.latency.n);
    println!(
        "  queueing delay  p50 {:>7.3}s   p95 {:>7.3}s   p99 {:>7.3}s",
        r.latency.queue_p50, r.latency.queue_p95, r.latency.queue_p99
    );
    println!(
        "  TTFT            p50 {:>7.3}s   p95 {:>7.3}s   p99 {:>7.3}s",
        r.latency.ttft_p50, r.latency.ttft_p95, r.latency.ttft_p99
    );
    println!(
        "  TPOT            p50 {:>6.2}ms   p95 {:>6.2}ms   p99 {:>6.2}ms",
        r.latency.tpot_p50 * 1e3,
        r.latency.tpot_p95 * 1e3,
        r.latency.tpot_p99 * 1e3
    );
    println!("\nper-tier traffic:");
    for t in &r.tier_stats {
        println!(
            "  {:<6} ×{}  migrated in {:>4} / out {:>4}  refusals {:>3}  admission refusals {:>3}",
            t.tier, t.instances, t.migrated_in, t.migrated_out, t.refusals, t.admission_refusals
        );
    }
    Ok(())
}
