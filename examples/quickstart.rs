//! Quickstart: load AOT artifacts, warm up the models, and generate with
//! speculative decoding — in ~40 lines of user code.
//!
//! ```bash
//! make artifacts                       # once
//! cargo run --release --example quickstart
//! ```

use std::path::PathBuf;

use rlhfspec::config::RunConfig;
use rlhfspec::coordinator::instance::DecodeMode;
use rlhfspec::rlhf::RlhfPipeline;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts/tiny".into()),
    );

    // One pipeline owns the four RLHF models + the generation fleet.
    let mut cfg = RunConfig::default();
    cfg.rlhf.instances = 1;
    cfg.rlhf.max_new_tokens = 24;
    let mut pipeline = RlhfPipeline::new(&dir, cfg, "gsm8k", 42)?;

    // Warm up: teach the actor the corpus, distill the draft SSM from it
    // (this is what makes speculative drafts get accepted).
    println!("pretraining actor…");
    let lm = pipeline.pretrain_actor(40, 3e-3)?;
    println!("  lm loss {:.3} → {:.3}", lm[0], lm.last().unwrap());
    println!("distilling draft…");
    let dl = pipeline.distill_draft(40, 3e-3)?;
    println!("  distill loss {:.3} → {:.3}", dl[0], dl.last().unwrap());

    // Generate with adaptive speculative decoding.
    pipeline.start_generation(DecodeMode::Adaptive)?;
    let report = pipeline.generate_once(4)?;
    println!(
        "\ngenerated {} samples in {:.2}s ({:.1} tok/s)",
        report.finished.len(),
        report.wall_secs,
        report.throughput_tokens()
    );
    for f in report.finished.iter().take(4) {
        let text = pipeline.tokenizer.decode_until_eos(&f.response);
        println!(
            "  sample {}: {:?} ({} rounds, {} drafts accepted)",
            f.id, text, f.rounds, f.drafts_accepted
        );
    }
    let acc: u64 = report.instances.iter().map(|r| r.metrics.drafts_accepted).sum();
    let prop: u64 = report.instances.iter().map(|r| r.metrics.drafts_proposed).sum();
    println!("draft acceptance: {}/{} = {:.1}%", acc, prop, 100.0 * acc as f64 / prop.max(1) as f64);
    pipeline.stop_generation();
    Ok(())
}
