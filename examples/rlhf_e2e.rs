//! End-to-end RLHF training driver — the full-system validation run.
//!
//! Trains a transformer from scratch with the complete RLHFSpec stack:
//! LM pretraining → SSM distillation → reward-model training → RLHF
//! iterations (speculative generation → inference → PPO training), with
//! per-iteration loss/reward curves logged and written to
//! `runs/rlhf_e2e_<config>.json`. The recorded runs live in
//! EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts                                        # tiny + small
//! cargo run --release --example rlhf_e2e                # small config
//! cargo run --release --example rlhf_e2e -- --artifacts artifacts/tiny --iters 4
//! ```

use std::path::PathBuf;

use rlhfspec::config::RunConfig;
use rlhfspec::coordinator::instance::DecodeMode;
use rlhfspec::rlhf::RlhfPipeline;
use rlhfspec::utils::cli::Args;
use rlhfspec::utils::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts/small"));
    let corpus = args.get_or("corpus", "gsm8k");
    let iters = args.usize_or("iters", 12);
    let pretrain = args.usize_or("pretrain", 150);
    let distill = args.usize_or("distill", 120);
    let reward_steps = args.usize_or("reward-steps", 40);
    let seed = args.u64_or("seed", 7);

    let mut cfg = RunConfig::default();
    cfg.seed = seed;
    cfg.rlhf.instances = args.usize_or("instances", 2);
    cfg.rlhf.samples_per_iter = args.usize_or("samples", 16);
    cfg.rlhf.max_new_tokens = args.usize_or("max-new", 48);
    cfg.rlhf.lr = 2e-4;
    cfg.spec.max_depth = 4;
    cfg.spec.max_draft = 16;
    cfg.realloc.cooldown = 6;
    cfg.realloc.threshold = 3;
    let mode = match args.get_or("mode", "adaptive").as_str() {
        "ar" => DecodeMode::Ar,
        m if m.starts_with("static") => DecodeMode::StaticSpec(8),
        _ => DecodeMode::Adaptive,
    };

    let mut p = RlhfPipeline::new(&dir, cfg, &corpus, seed)?;
    println!(
        "== RLHFSpec e2e: config={} corpus={corpus} actor={} params draft={} params ==",
        p.manifest.config_name,
        p.manifest.target.n_params(),
        p.manifest.draft.n_params()
    );

    // Warm-up checkpoints: reuse across runs unless --fresh.
    std::fs::create_dir_all("runs").ok();
    let cfg_name = p.manifest.config_name.clone();
    let corpus_name = corpus.clone();
    let ck = move |m: &str| format!("runs/ckpt_{cfg_name}_{corpus_name}_{m}.bin");
    let have_ckpt = ["actor", "draft", "reward"]
        .iter()
        .all(|m| std::path::Path::new(&ck(m)).exists());
    let mut lm = Vec::new();
    let mut dl = Vec::new();
    if have_ckpt && !args.flag("fresh") {
        println!("[warmup  ] loading checkpoints from runs/ (use --fresh to retrain)");
        p.actor.load(std::path::Path::new(&ck("actor")))?;
        p.draft.load(std::path::Path::new(&ck("draft")))?;
        p.reward.load(std::path::Path::new(&ck("reward")))?;
        p.freeze_reference()?;
    } else {
        // Phase 1: LM pretraining (stands in for a pretrained ckpt).
        let t0 = std::time::Instant::now();
        lm = p.pretrain_actor(pretrain, 3e-3)?;
        println!(
            "[pretrain] {} steps, loss {:.3} → {:.3} ({:.1}s)",
            lm.len(),
            lm[0],
            lm.last().unwrap(),
            t0.elapsed().as_secs_f64()
        );
        p.freeze_reference()?;

        // Phase 2: distill the draft SSM (earns the Fig-7 correlation).
        let t0 = std::time::Instant::now();
        dl = p.distill_draft(distill, 3e-3)?;
        println!(
            "[distill ] {} steps, KL {:.3} → {:.3} ({:.1}s)",
            dl.len(),
            dl[0],
            dl.last().unwrap(),
            t0.elapsed().as_secs_f64()
        );

        // Phase 3: Bradley-Terry reward model.
        let rl = p.train_reward(reward_steps, 3e-3)?;
        println!("[reward  ] {} steps, BT loss {:.3} → {:.3}", rl.len(), rl[0], rl.last().unwrap());
        p.actor.save(std::path::Path::new(&ck("actor")))?;
        p.draft.save(std::path::Path::new(&ck("draft")))?;
        p.reward.save(std::path::Path::new(&ck("reward")))?;
    }

    // Phase 4: the RLHF loop.
    p.start_generation(mode)?;
    println!(
        "\n{:>4} {:>8} {:>9} {:>9} {:>6} {:>8} {:>8} {:>8} {:>7} {:>5}",
        "iter", "gen(s)", "infer(s)", "train(s)", "gen%", "reward", "resp-len", "ppoloss", "accept", "mig"
    );
    let mut history = Vec::new();
    for _ in 0..iters {
        let (st, report) = p.iteration()?;
        println!(
            "{:>4} {:>8.2} {:>9.2} {:>9.2} {:>5.1}% {:>8.3} {:>8.1} {:>8.4} {:>6.1}% {:>5}",
            st.iter,
            st.gen_secs,
            st.infer_secs,
            st.train_secs,
            100.0 * st.gen_fraction(),
            st.mean_reward,
            st.mean_response_len,
            st.ppo_loss,
            100.0 * st.accept_rate,
            report.migrations,
        );
        history.push(st);
    }
    p.stop_generation();

    // Reward trend over the run.
    let k = (history.len() / 3).max(1);
    let early: f64 = history.iter().take(k).map(|s| s.mean_reward).sum::<f64>() / k as f64;
    let late: f64 =
        history.iter().rev().take(k).map(|s| s.mean_reward).sum::<f64>() / k as f64;
    println!("\nmean reward: first third {early:.3} → last third {late:.3}");
    let gen_share: f64 =
        history.iter().map(|s| s.gen_fraction()).sum::<f64>() / history.len() as f64;
    println!("mean generation share of iteration: {:.1}%", 100.0 * gen_share);

    // Persist the run record.
    std::fs::create_dir_all("runs").ok();
    let rows: Vec<Json> = history
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("iter", Json::num(s.iter as f64)),
                ("gen_secs", Json::num(s.gen_secs)),
                ("infer_secs", Json::num(s.infer_secs)),
                ("train_secs", Json::num(s.train_secs)),
                ("mean_reward", Json::num(s.mean_reward)),
                ("resp_len", Json::num(s.mean_response_len)),
                ("ppo_loss", Json::num(s.ppo_loss)),
                ("kl", Json::num(s.kl)),
                ("value_loss", Json::num(s.value_loss)),
                ("accept_rate", Json::num(s.accept_rate)),
            ])
        })
        .collect();
    let record = Json::obj(vec![
        ("config", Json::str(&p.manifest.config_name)),
        ("corpus", Json::str(&corpus)),
        ("seed", Json::num(seed as f64)),
        ("actor_params", Json::num(p.manifest.target.n_params() as f64)),
        ("pretrain_loss", Json::arr_f64(&lm.iter().map(|&x| x as f64).collect::<Vec<_>>())),
        ("distill_loss", Json::arr_f64(&dl.iter().map(|&x| x as f64).collect::<Vec<_>>())),
        ("iterations", Json::Arr(rows)),
    ]);
    let path = format!("runs/rlhf_e2e_{}.json", p.manifest.config_name);
    std::fs::write(&path, record.to_string())?;
    println!("run record written to {path}");
    Ok(())
}
