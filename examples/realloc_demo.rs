//! Reallocation demo: skewed load across two real instances, with the
//! two-stage KV migration running over actual channels (paper §6).
//!
//! Also prints the paper-scale simulated counterpart (Fig 14) so the real
//! and simulated substrates can be eyeballed side by side.
//!
//! ```bash
//! cargo run --release --example realloc_demo -- --artifacts artifacts/tiny
//! ```

use std::path::PathBuf;

use rlhfspec::config::RunConfig;

use rlhfspec::coordinator::instance::{DecodeMode, SampleTask};
use rlhfspec::runtime::{Manifest, ModelStore};
use rlhfspec::sim::cluster::{ClusterConfig, SimCluster};
use rlhfspec::utils::cli::Args;
use rlhfspec::utils::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts/tiny"));
    let seed = args.u64_or("seed", 11);

    // ---- real path: 2 PJRT instances, skewed max-new-tokens ----------
    let man = std::rc::Rc::new(Manifest::load(&dir)?);
    let target = ModelStore::init(&man, "target", 1)?;
    let draft = ModelStore::init(&man, "draft", 2)?;
    let tw = target.weights_host()?;
    let dw = draft.weights_host()?;

    let mut rng = Rng::new(seed);
    let mut tasks = Vec::new();
    for i in 0..24u64 {
        tasks.push(SampleTask {
            id: i,
            prompt: (0..6).map(|_| rng.below(60) as i32 + 1).collect(),
            // round-robin allocation sends the long ones to instance 0
            max_new_tokens: if i % 2 == 0 { 44 } else { 3 },
            eos: 0,
            submitted_at: None,
        });
    }

    let run = |realloc: bool, tasks: Vec<SampleTask>| -> anyhow::Result<_> {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        cfg.rlhf.instances = 2;
        cfg.spec.max_depth = 3;
        cfg.spec.max_draft = 8;
        cfg.realloc.enabled = realloc;
        cfg.realloc.cooldown = 3;
        cfg.realloc.threshold = 3;
        let mut svc = rlhfspec::coordinator::driver::GenerationService::start(
            &dir,
            &cfg,
            DecodeMode::Adaptive,
            &tw,
            &dw,
        )?;
        // Warm both instances' executable caches so the timed batch
        // measures decoding, not lazy XLA compilation.
        let warm: Vec<SampleTask> = (100..104u64)
            .map(|id| SampleTask {
                id,
                prompt: vec![1, 2, 3, 4],
                max_new_tokens: 3,
                eos: 0,
                submitted_at: None,
            })
            .collect();
        svc.run_batch(warm)?;
        let report = svc.run_batch(tasks)?;
        svc.shutdown();
        Ok(report)
    };

    println!("== real path (2 PJRT instances, 24 skewed samples) ==");
    let with = run(true, tasks.clone())?;
    let without = run(false, tasks)?;
    println!(
        "  without realloc: {:.2}s wall, {:.0} tok/s",
        without.wall_secs,
        without.throughput_tokens()
    );
    println!(
        "  with realloc   : {:.2}s wall, {:.0} tok/s | {} migration orders, {} refusals, SRD {:.2}ms",
        with.wall_secs,
        with.throughput_tokens(),
        with.migrations,
        with.migration_refusals,
        with.srd_secs * 1e3
    );
    for r in &with.instances {
        println!(
            "    instance {}: migrated in {} / out {}, tokens {}",
            r.id,
            r.metrics.samples_migrated_in,
            r.metrics.samples_migrated_out,
            r.metrics.tokens_out
        );
    }

    // ---- paper-scale simulation (Fig 14) ------------------------------
    println!("\n== simulated paper scale (Fig 14 scenario) ==");
    let mut rng = Rng::new(seed);
    let long: Vec<usize> = (0..20).map(|_| 1100 + rng.below(900)).collect();
    let short: Vec<usize> = (0..20).map(|_| 60 + rng.below(240)).collect();
    for (label, enabled) in [("without realloc", false), ("with realloc   ", true)] {
        let cfg = ClusterConfig {
            instances: 2,
            realloc_enabled: enabled,
            cooldown: 24,
            n_samples: 0,
            seed,
            ..Default::default()
        };
        let r = SimCluster::with_assignment(cfg, vec![long.clone(), short.clone()]).run();
        println!(
            "  {label}: {:>7.0} tok/s, makespan {:>5.0}s, migrations {}, downtime {:.1}ms",
            r.tokens_per_sec(),
            r.makespan,
            r.migrations,
            r.migration_downtime * 1e3
        );
    }
    Ok(())
}
