//! Record a Perfetto-loadable trace of a simulated cluster run.
//!
//! Runs the mixed-GPU (hetero) fleet — 4×h100 + 4×a100 + 8×l40s with
//! per-tier reallocation knees — as a streaming workload with the
//! `[trace]` plane enabled, then points at the two files it wrote:
//!
//! * `trace.json` — Chrome trace-event timeline: open it at
//!   <https://ui.perfetto.dev> (or `chrome://tracing`) to see one lane
//!   per instance (decode rounds, migration legs, downtime) plus the
//!   control-plane / engine lanes;
//! * `trace_metrics.json` — counters, histograms and the per-instance
//!   stage-seconds breakdown; summarize with
//!   `python3 scripts/trace_summary.py trace.json`.
//!
//! ```bash
//! cargo run --release --example record_trace -- --out trace.json
//! python3 scripts/trace_summary.py trace.json
//! ```
//!
//! The run is also executed with tracing *off* first and the two
//! results are compared — a live demonstration of the bit-inertness
//! contract the `[trace]` plane guarantees (see docs/ARCHITECTURE.md
//! § Observability).

use rlhfspec::data::arrivals::ArrivalProcess;
use rlhfspec::sim::cluster::{ClusterConfig, FleetTier, SimCluster};
use rlhfspec::sim::TraceConfig;
use rlhfspec::utils::cli::Args;

fn cfg(seed: u64, n_samples: usize, trace: TraceConfig) -> ClusterConfig {
    ClusterConfig {
        fleet: vec![
            FleetTier::preset("h100", 4).expect("preset"),
            FleetTier::preset("a100", 4).expect("preset"),
            FleetTier::preset("l40s", 8).expect("preset"),
        ],
        cooldown: 16,
        n_samples,
        max_tokens: 384,
        pending_bound: 64,
        seed,
        trace,
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let out = args.get_or("out", "trace.json");
    let seed = args.u64_or("seed", 17);
    let n_samples = args.usize_or("samples", 384);
    let rate = args.f64_or("rate", 48.0);

    // Baseline: the identical run, untraced.
    let mut base = SimCluster::streaming(
        cfg(seed, n_samples, TraceConfig::off()),
        &ArrivalProcess::poisson(rate),
    )?;
    let base_res = base.run();

    // Traced run.
    let trace = TraceConfig::to_path(&out);
    let metrics_out = trace.metrics_out.clone();
    let mut traced =
        SimCluster::streaming(cfg(seed, n_samples, trace), &ArrivalProcess::poisson(rate))?;
    let res = traced.run();

    assert_eq!(
        (base_res.total_tokens, base_res.makespan.to_bits()),
        (res.total_tokens, res.makespan.to_bits()),
        "tracing must be bit-inert"
    );
    println!(
        "{} instances, {} samples over {:.1} virtual s: {} tokens, \
         {} migrations, {} realloc decisions (bit-identical to the \
         untraced run)",
        16, res.n_samples, res.makespan, res.total_tokens, res.migrations, res.realloc_decisions,
    );
    println!("wrote {out} — open at https://ui.perfetto.dev");
    println!("wrote {metrics_out}");
    println!("summarize: python3 scripts/trace_summary.py {out}");
    Ok(())
}
