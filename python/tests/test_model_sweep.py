"""Hypothesis sweep at the *model* level: fwd_tree(pallas) == fwd_tree(ref)
across batch sizes, tree shapes, prefixes and cache states — catches
RoPE/mask/cache integration bugs that kernel-level tests can't see."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.configs import get_config
from compile import model as M

CFG = get_config("tiny")
T_CFG = CFG.target
WS = M.init_weights(T_CFG, jax.random.PRNGKey(99))


def _random_tree(rng, B, T):
    """Random ancestor masks + consistent depths/positions."""
    parent = np.full((B, T), -1, np.int64)
    depth = np.zeros((B, T), np.int64)
    mask = np.zeros((B, T, T), np.float32)
    for b in range(B):
        for i in range(T):
            mask[b, i, i] = 1.0
            if i > 0:
                p = int(rng.integers(0, i))
                parent[b, i] = p
                depth[b, i] = depth[b, p] + 1
                mask[b, i] = np.maximum(mask[b, i], mask[b, p])
                mask[b, i, i] = 1.0
    return depth, mask


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    t=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwd_tree_pallas_matches_ref(b, t, seed):
    rng = np.random.default_rng(seed)
    L, H, Dh, S = T_CFG.n_layers, T_CFG.n_heads, T_CFG.d_head, T_CFG.max_seq
    kc = jnp.asarray(rng.standard_normal((L, b, H, S, Dh)) * 0.3, jnp.float32)
    vc = jnp.asarray(rng.standard_normal((L, b, H, S, Dh)) * 0.3, jnp.float32)
    prefix = jnp.asarray(rng.integers(0, 20, b), jnp.int32)
    tokens = jnp.asarray(rng.integers(0, T_CFG.vocab, (b, t)), jnp.int32)
    depth, mask = _random_tree(rng, b, t)
    positions = jnp.asarray(np.asarray(prefix)[:, None] + depth, jnp.int32)
    mask = jnp.asarray(mask)

    out_p, kp, vp = M.fwd_tree(T_CFG, WS, kc, vc, tokens, positions, prefix,
                               mask, attn="pallas", blk_k=CFG.blk_k)
    out_r, kr, vr = M.fwd_tree(T_CFG, WS, kc, vc, tokens, positions, prefix,
                               mask, attn="ref", blk_k=CFG.blk_k)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(kp), np.asarray(kr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(vp), np.asarray(vr), atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_value_and_reward_heads_finite(seed):
    rng = np.random.default_rng(seed)
    cws = M.init_weights(CFG.critic, jax.random.PRNGKey(seed % 1000), "value")
    toks = jnp.asarray(rng.integers(0, CFG.critic.vocab, (2, 16)), jnp.int32)
    (vals,) = M.value_fwd(CFG.critic, cws, toks)
    assert np.isfinite(np.asarray(vals)).all()

    rws = M.init_weights(CFG.reward, jax.random.PRNGKey(seed % 997), "reward")
    last = jnp.asarray(rng.integers(0, 16, 2), jnp.int32)
    (r,) = M.reward_fwd(CFG.reward, rws, toks, last)
    assert np.isfinite(np.asarray(r)).all()
    assert r.shape == (2,)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_logits_permutation_equivariance_over_batch(seed):
    """Permuting batch rows permutes outputs identically (no cross-batch
    leakage — the invariant that makes sample migration sound)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, T_CFG.vocab, (2, 10))
    a = M.logits_fwd(T_CFG, WS, jnp.asarray(toks, jnp.int32))[0]
    b = M.logits_fwd(T_CFG, WS, jnp.asarray(toks[::-1].copy(), jnp.int32))[0]
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[1]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[0]), atol=1e-5)
