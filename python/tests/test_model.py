"""L2 correctness: fwd_tree + host-style commit == dense causal forward.

These tests pin down the exact contract the rust coordinator relies on:
incremental decoding with the functional KV cache (commit the returned
tree rows, advance prefix_len) must reproduce the dense full-sequence
forward logits position-for-position.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import get_config
from compile import model as M

CFG = get_config("tiny")
T_CFG = CFG.target


def _init(key=0, cfg=T_CFG, head="lm"):
    return M.init_weights(cfg, jax.random.PRNGKey(key), head)


def _empty_cache(cfg, B):
    L, H, Dh, S = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.max_seq
    z = jnp.zeros((L, B, H, S, Dh), jnp.float32)
    return z, z


def _chain_mask(B, T):
    m = np.tril(np.ones((T, T), np.float32))
    return jnp.asarray(np.broadcast_to(m, (B, T, T)).copy())


def _host_commit(kc, vc, k_new, v_new, b, src, dest):
    """Mimic the rust host-side scatter: cache[:, b, :, dest, :] = new[:, b, :, src, :]."""
    kc = kc.at[:, b, :, dest, :].set(k_new[:, b, :, src, :])
    vc = vc.at[:, b, :, dest, :].set(v_new[:, b, :, src, :])
    return kc, vc


def _decode_incremental(ws, tokens_row, attn="ref"):
    """Feed tokens one at a time through fwd_tree(T=1), committing each."""
    B = 1
    kc, vc = _empty_cache(T_CFG, B)
    outs = []
    for pos, tok in enumerate(tokens_row):
        t = jnp.asarray([[tok]], jnp.int32)
        p = jnp.asarray([[pos]], jnp.int32)
        plen = jnp.asarray([pos], jnp.int32)
        mask = jnp.ones((B, 1, 1), jnp.float32)
        logits, k_new, v_new = M.fwd_tree(
            T_CFG, ws, kc, vc, t, p, plen, mask, attn=attn, blk_k=CFG.blk_k)
        kc, vc = _host_commit(kc, vc, k_new, v_new, 0, 0, pos)
        outs.append(logits[0, 0])
    return jnp.stack(outs)  # [S, V]


class TestIncrementalEquivalence:
    @pytest.mark.parametrize("attn", ["ref", "pallas"])
    def test_decode_matches_causal(self, attn):
        """Token-by-token decode == dense causal forward."""
        ws = _init()
        rng = np.random.default_rng(0)
        toks = rng.integers(0, T_CFG.vocab, size=12).tolist()
        inc = _decode_incremental(ws, toks, attn=attn)
        dense = M.logits_fwd(T_CFG, ws, jnp.asarray([toks], jnp.int32))[0][0]
        np.testing.assert_allclose(np.asarray(inc), np.asarray(dense),
                                   atol=2e-4, rtol=2e-4)

    @pytest.mark.parametrize("attn", ["ref", "pallas"])
    def test_prefill_chunk_matches_causal(self, attn):
        """One prefill chunk (T=8, causal mask) == dense forward prefix."""
        ws = _init()
        rng = np.random.default_rng(1)
        toks = rng.integers(0, T_CFG.vocab, size=8)
        B, T = 1, 8
        kc, vc = _empty_cache(T_CFG, B)
        t = jnp.asarray(toks[None, :], jnp.int32)
        p = jnp.asarray(np.arange(T)[None, :], jnp.int32)
        plen = jnp.zeros((B,), jnp.int32)
        logits, _, _ = M.fwd_tree(T_CFG, ws, kc, vc, t, p, plen,
                                  _chain_mask(B, T), attn=attn,
                                  blk_k=CFG.blk_k)
        pad = np.zeros((1, 12), np.int64)
        pad[0, :8] = toks
        dense = M.logits_fwd(T_CFG, ws, jnp.asarray(pad, jnp.int32))[0][0, :8]
        np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(dense),
                                   atol=2e-4, rtol=2e-4)

    def test_tree_verify_branch_equivalence(self):
        """Each tree branch's logits == the logits of decoding that branch
        as a plain chain (the Markov property §6.2 exploits)."""
        ws = _init()
        rng = np.random.default_rng(2)
        prefix = rng.integers(0, T_CFG.vocab, size=6).tolist()

        # Prefill the prefix.
        B = 1
        kc, vc = _empty_cache(T_CFG, B)
        T = len(prefix)
        logits_p, k_new, v_new = M.fwd_tree(
            T_CFG, ws, kc, vc,
            jnp.asarray([prefix], jnp.int32),
            jnp.asarray(np.arange(T)[None, :], jnp.int32),
            jnp.zeros((B,), jnp.int32),
            _chain_mask(B, T), attn="ref", blk_k=CFG.blk_k)
        for i in range(T):
            kc, vc = _host_commit(kc, vc, k_new, v_new, 0, i, i)

        # A 5-node tree: root a with children b,c; b has children d,e.
        #   idx: 0=a 1=b 2=c 3=d 4=e ; depths 0,1,1,2,2
        toks = rng.integers(0, T_CFG.vocab, size=5).tolist()
        depth = [0, 1, 1, 2, 2]
        parent = [-1, 0, 0, 1, 1]
        Tt = 5
        mask = np.zeros((B, Tt, Tt), np.float32)
        for i in range(Tt):
            j = i
            while j >= 0:
                mask[0, i, j] = 1.0
                j = parent[j]
        pos = jnp.asarray([[T + d for d in depth]], jnp.int32)
        plen = jnp.asarray([T], jnp.int32)
        tree_logits, _, _ = M.fwd_tree(
            T_CFG, ws, kc, vc, jnp.asarray([toks], jnp.int32), pos, plen,
            jnp.asarray(mask), attn="ref", blk_k=CFG.blk_k)

        # Branch a→b→d decoded as a chain must match tree rows 0,1,3.
        # Positions: dense[i] = logits after token i; tree row r sits at
        # dense index T + depth(r).
        chain = prefix + [toks[0], toks[1], toks[3]]
        dense = M.logits_fwd(T_CFG, ws, jnp.asarray([chain], jnp.int32))[0][0]
        np.testing.assert_allclose(np.asarray(tree_logits[0, 0]),
                                   np.asarray(dense[T]), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(tree_logits[0, 1]),
                                   np.asarray(dense[T + 1]), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(tree_logits[0, 3]),
                                   np.asarray(dense[T + 2]), atol=2e-4, rtol=2e-4)

    def test_batch_independence(self):
        """Sample b's outputs don't depend on other rows in the batch —
        the invariant that makes migration/batch-composition legal."""
        ws = _init()
        rng = np.random.default_rng(3)
        toks = rng.integers(0, T_CFG.vocab, size=(2, 4))
        B, T = 2, 4
        kc, vc = _empty_cache(T_CFG, B)
        p = jnp.asarray(np.broadcast_to(np.arange(T), (B, T)).copy(), jnp.int32)
        plen = jnp.zeros((B,), jnp.int32)
        both, _, _ = M.fwd_tree(T_CFG, ws, kc, vc,
                                jnp.asarray(toks, jnp.int32), p, plen,
                                _chain_mask(B, T), attn="ref", blk_k=CFG.blk_k)
        kc1, vc1 = _empty_cache(T_CFG, 1)
        solo, _, _ = M.fwd_tree(T_CFG, ws, kc1, vc1,
                                jnp.asarray(toks[1:2], jnp.int32), p[:1], plen[:1],
                                _chain_mask(1, T), attn="ref", blk_k=CFG.blk_k)
        np.testing.assert_allclose(np.asarray(both[1]), np.asarray(solo[0]),
                                   atol=1e-5, rtol=1e-5)


class TestCommitExecutable:
    def test_commit_matches_host_scatter(self):
        """The jax commit (kept for tests) == the host-side scatter."""
        rng = np.random.default_rng(4)
        L, B, H, S, Dh, T = (T_CFG.n_layers, 2, T_CFG.n_heads, 16,
                             T_CFG.d_head, 4)
        kc = jnp.asarray(rng.standard_normal((L, B, H, S, Dh)), jnp.float32)
        vc = jnp.asarray(rng.standard_normal((L, B, H, S, Dh)), jnp.float32)
        kn = jnp.asarray(rng.standard_normal((L, B, H, T, Dh)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((L, B, H, T, Dh)), jnp.float32)
        src = jnp.asarray([[0, 2, 0, 0], [1, 3, 0, 0]], jnp.int32)
        dst = jnp.asarray([[5, 6, 0, 0], [7, 8, 0, 0]], jnp.int32)
        val = jnp.asarray([[1, 1, 0, 0], [1, 1, 0, 0]], jnp.float32)
        kc2, vc2 = M.commit(T_CFG, kc, vc, kn, vn, src, dst, val)

        kc_ref, vc_ref = kc, vc
        for b in range(B):
            for a in range(4):
                if val[b, a] > 0.5:
                    kc_ref, vc_ref = _host_commit(
                        kc_ref, vc_ref, kn, vn, b, int(src[b, a]), int(dst[b, a]))
        np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref), atol=0)
        np.testing.assert_allclose(np.asarray(vc2), np.asarray(vc_ref), atol=0)


class TestHeads:
    def test_value_fwd_shape(self):
        ws = _init(cfg=CFG.critic, head="value")
        toks = jnp.zeros((2, 8), jnp.int32)
        (vals,) = M.value_fwd(CFG.critic, ws, toks)
        assert vals.shape == (2, 8)
        assert np.isfinite(np.asarray(vals)).all()

    def test_reward_fwd_uses_last_pos(self):
        ws = _init(cfg=CFG.reward, head="reward")
        rng = np.random.default_rng(5)
        toks = jnp.asarray(rng.integers(0, 60, (2, 8)), jnp.int32)
        (r1,) = M.reward_fwd(CFG.reward, ws, toks, jnp.asarray([3, 7], jnp.int32))
        (vals_full,) = (M.value_fwd(CFG.reward, ws, toks),)
        # reward = the reward-head value at last_pos; check consistency by
        # recomputing with the same position twice.
        (r2,) = M.reward_fwd(CFG.reward, ws, toks, jnp.asarray([3, 7], jnp.int32))
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2))

    def test_logprobs_fwd_is_log_softmax_gather(self):
        ws = _init()
        rng = np.random.default_rng(6)
        toks = jnp.asarray(rng.integers(0, T_CFG.vocab, (1, 8)), jnp.int32)
        (lp,) = M.logprobs_fwd(T_CFG, ws, toks)
        (lg,) = M.logits_fwd(T_CFG, ws, toks)
        ref = jax.nn.log_softmax(lg[:, :-1], axis=-1)
        ref = jnp.take_along_axis(ref, toks[:, 1:, None], axis=-1)[..., 0]
        np.testing.assert_allclose(np.asarray(lp), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        assert (np.asarray(lp) <= 1e-6).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=st.sampled_from([1, 2, 4, 8]))
def test_rope_shift_invariance(seed, t):
    """RoPE depends only on relative offsets: rotating q and k by the same
    extra offset leaves q·k scores unchanged."""
    rng = np.random.default_rng(seed)
    B, H, Dh = 1, 2, 8
    q = jnp.asarray(rng.standard_normal((B, t, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, t, H, Dh)), jnp.float32)
    p0 = jnp.asarray(rng.integers(0, 16, (B, t)), jnp.int32)
    shift = int(rng.integers(0, 10))
    q1, k1 = M.rope(q, p0), M.rope(k, p0)
    q2, k2 = M.rope(q, p0 + shift), M.rope(k, p0 + shift)
    s1 = jnp.einsum("bthd,bshd->bhts", q1, k1)
    s2 = jnp.einsum("bthd,bshd->bhts", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-3, rtol=1e-3)
