"""L1 correctness: Pallas tree-attention kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the verification hot path —
hypothesis sweeps shapes/dtypes/masks and asserts allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref
from compile.kernels.tree_attention import tree_attention, vmem_bytes


def _rand(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _chain_mask(B, T):
    """Causal chain: token i sees 0..i (degenerate tree)."""
    m = np.tril(np.ones((T, T), np.float32))
    return jnp.asarray(np.broadcast_to(m, (B, T, T)).copy())


def _random_tree_mask(rng, B, T):
    """Random forest: each token's parent is an earlier token (or none);
    mask = ancestor-or-self closure."""
    m = np.zeros((B, T, T), np.float32)
    for b in range(B):
        for i in range(T):
            m[b, i, i] = 1.0
            if i > 0 and rng.random() < 0.8:
                p = int(rng.integers(0, i))
                m[b, i] = np.maximum(m[b, i], m[b, p])
                m[b, i, i] = 1.0
    return jnp.asarray(m)


def _run_both(rng, B, H, T, Dh, S, blk_k, plen, mask):
    q = _rand(rng, (B, H, T, Dh))
    kc = _rand(rng, (B, H, S, Dh))
    vc = _rand(rng, (B, H, S, Dh))
    kt = _rand(rng, (B, H, T, Dh))
    vt = _rand(rng, (B, H, T, Dh))
    plen = jnp.asarray(plen, jnp.int32)
    out = tree_attention(q, kc, vc, kt, vt, plen, mask, blk_k=blk_k)
    ref = tree_attention_ref(q, kc, vc, kt, vt, plen, mask)
    return np.asarray(out), np.asarray(ref)


class TestBasic:
    def test_matches_ref_simple(self):
        rng = np.random.default_rng(0)
        out, ref = _run_both(rng, 2, 2, 8, 16, 64, 32, [5, 17],
                             _chain_mask(2, 8))
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_zero_prefix(self):
        """Empty cache: only the tree tokens participate."""
        rng = np.random.default_rng(1)
        out, ref = _run_both(rng, 1, 2, 4, 8, 32, 32, [0],
                             _chain_mask(1, 4))
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_full_prefix(self):
        """Cache completely full."""
        rng = np.random.default_rng(2)
        out, ref = _run_both(rng, 1, 2, 4, 8, 32, 16, [32],
                             _chain_mask(1, 4))
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_self_only_mask(self):
        """Padding rows: token sees only itself, zero prefix — stays finite."""
        rng = np.random.default_rng(3)
        B, T = 1, 4
        m = np.zeros((B, T, T), np.float32)
        for i in range(T):
            m[0, i, i] = 1.0
        out, ref = _run_both(rng, B, 2, T, 8, 32, 32, [0], jnp.asarray(m))
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_single_token_decode(self):
        """T=1 degenerates to ordinary incremental decode attention."""
        rng = np.random.default_rng(4)
        out, ref = _run_both(rng, 2, 4, 1, 16, 64, 32, [10, 63],
                             _chain_mask(2, 1))
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_blk_k_must_divide(self):
        rng = np.random.default_rng(5)
        with pytest.raises(ValueError):
            _run_both(rng, 1, 1, 1, 8, 48, 32, [0], _chain_mask(1, 1))

    def test_large_scale_values(self):
        """Softmax stability under large score magnitudes."""
        rng = np.random.default_rng(6)
        B, H, T, Dh, S = 1, 2, 4, 8, 32
        q = _rand(rng, (B, H, T, Dh), scale=30.0)
        kc = _rand(rng, (B, H, S, Dh), scale=30.0)
        vc = _rand(rng, (B, H, S, Dh))
        kt = _rand(rng, (B, H, T, Dh), scale=30.0)
        vt = _rand(rng, (B, H, T, Dh))
        plen = jnp.asarray([20], jnp.int32)
        mask = _chain_mask(B, T)
        out = tree_attention(q, kc, vc, kt, vt, plen, mask, blk_k=32)
        ref = tree_attention_ref(q, kc, vc, kt, vt, plen, mask)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.sampled_from([1, 2, 4, 8, 16]),
    dh=st.sampled_from([4, 8, 16, 32]),
    ntiles=st.integers(1, 4),
    blk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(b, h, t, dh, ntiles, blk, seed):
    """Property: kernel == oracle across the shape/mask/prefix space."""
    rng = np.random.default_rng(seed)
    S = ntiles * blk
    plen = rng.integers(0, S + 1, size=b).tolist()
    mask = _random_tree_mask(rng, b, t)
    out, ref = _run_both(rng, b, h, t, dh, S, blk, plen, mask)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_bf16(seed):
    """bf16 inputs stay finite and roughly match the f32 oracle."""
    rng = np.random.default_rng(seed)
    B, H, T, Dh, S = 1, 2, 4, 16, 32
    mk = lambda s: jnp.asarray(rng.standard_normal(s), jnp.bfloat16)
    q, kc, vc, kt, vt = (mk((B, H, T, Dh)), mk((B, H, S, Dh)), mk((B, H, S, Dh)),
                         mk((B, H, T, Dh)), mk((B, H, T, Dh)))
    plen = jnp.asarray([S // 2], jnp.int32)
    mask = _chain_mask(B, T)
    out = tree_attention(q, kc, vc, kt, vt, plen, mask, blk_k=32)
    f = lambda x: x.astype(jnp.float32)
    ref = tree_attention_ref(f(q), f(kc), f(vc), f(kt), f(vt), plen, mask)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=0.05, rtol=0.05)


class TestVmemModel:
    def test_footprint_independent_of_seq(self):
        """The flash-style tile loop keeps VMEM independent of S."""
        a = vmem_bytes(T=16, S=384, Dh=128, blk_k=128)
        b = vmem_bytes(T=16, S=4096, Dh=128, blk_k=128)
        assert a == b

    def test_fits_tpu_vmem(self):
        """Paper-scale shapes fit a 16 MiB TPU VMEM with double buffering."""
        assert 2 * vmem_bytes(T=64, S=2048, Dh=128, blk_k=256) < 16 * 2**20
