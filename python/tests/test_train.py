"""Training-step correctness: losses decrease, Adam math, PPO semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import get_config
from compile import model as M

CFG = get_config("tiny")


def _zeros_like_ws(ws):
    return [jnp.zeros_like(w) for w in ws]


def _run_steps(step_fn, ws, n, *data):
    m, v = _zeros_like_ws(ws), _zeros_like_ws(ws)
    step = jnp.asarray(0.0)
    nw = len(ws)
    losses = []
    for _ in range(n):
        out = step_fn(ws, m, v, step, *data)
        losses.append(float(out[0]))
        ws = list(out[1 : 1 + nw])
        m = list(out[1 + nw : 1 + 2 * nw])
        v = list(out[1 + 2 * nw : 1 + 3 * nw])
        step = out[1 + 3 * nw]
    return losses, ws


class TestLM:
    def test_lm_loss_decreases_overfit(self):
        """A tiny model overfits one batch: loss must drop substantially."""
        ws = M.init_weights(CFG.target, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, CFG.target.vocab,
                                        (CFG.train_batch, CFG.train_seq)), jnp.int32)
        mask = jnp.ones((CFG.train_batch, CFG.train_seq), jnp.float32)
        fn = jax.jit(lambda w, m, v, s, t, msk: M.train_lm_step(
            CFG.target, w, m, v, s, t, msk, 1e-2))
        losses, _ = _run_steps(fn, ws, 30, toks, mask)
        assert losses[-1] < losses[0] * 0.7, losses

    def test_masked_positions_ignored(self):
        """Zero-mask rows contribute nothing to the LM loss."""
        ws = M.init_weights(CFG.target, jax.random.PRNGKey(1))
        rng = np.random.default_rng(1)
        t1 = rng.integers(0, CFG.target.vocab, (CFG.train_batch, CFG.train_seq))
        t2 = t1.copy()
        t2[0] = rng.integers(0, CFG.target.vocab, CFG.train_seq)  # row 0 differs
        mask = np.ones((CFG.train_batch, CFG.train_seq), np.float32)
        mask[0] = 0.0
        l1 = M._lm_loss(CFG.target, ws, jnp.asarray(t1, jnp.int32), jnp.asarray(mask))
        l2 = M._lm_loss(CFG.target, ws, jnp.asarray(t2, jnp.int32), jnp.asarray(mask))
        # Row 0 differs BUT is masked out of the loss *numerator*; remaining
        # rows are identical, so losses match.
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


class TestDistill:
    def test_distill_converges_toward_target(self):
        """Draft KL to a fixed target distribution decreases."""
        tws = M.init_weights(CFG.target, jax.random.PRNGKey(2))
        dws = M.init_weights(CFG.draft, jax.random.PRNGKey(3))
        rng = np.random.default_rng(2)
        toks = jnp.asarray(rng.integers(0, CFG.target.vocab,
                                        (CFG.train_batch, CFG.train_seq)), jnp.int32)
        (tlogits,) = M.logits_fwd(CFG.target, tws, toks)
        mask = jnp.ones((CFG.train_batch, CFG.train_seq), jnp.float32)
        fn = jax.jit(lambda w, m, v, s, t, tl, msk: M.distill_step(
            CFG.draft, w, m, v, s, t, tl, msk, 1e-2))
        losses, _ = _run_steps(fn, dws, 30, toks, tlogits, mask)
        assert losses[-1] < losses[0] * 0.8, losses


class TestAdam:
    def test_adam_single_param_matches_reference(self):
        """One scalar-ish param: compare against a hand-rolled Adam step."""
        w = jnp.asarray([2.0, -3.0])
        g = jnp.asarray([0.5, -1.0])
        m = jnp.zeros(2)
        v = jnp.zeros(2)
        lr, b1, b2, eps = 0.1, 0.9, 0.999, 1e-8
        ws2, m2, v2, step2 = M.adam_update([w], [g], [m], [v],
                                           jnp.asarray(0.0), lr)
        m_ref = (1 - b1) * np.asarray(g)
        v_ref = (1 - b2) * np.asarray(g) ** 2
        mhat = m_ref / (1 - b1)
        vhat = v_ref / (1 - b2)
        w_ref = np.asarray(w) - lr * mhat / (np.sqrt(vhat) + eps)
        np.testing.assert_allclose(np.asarray(ws2[0]), w_ref, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(m2[0]), m_ref, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(v2[0]), v_ref, rtol=1e-6)
        assert float(step2) == 1.0


class TestPPO:
    def _setup(self):
        ws = M.init_weights(CFG.target, jax.random.PRNGKey(4))
        rng = np.random.default_rng(4)
        B, S = CFG.train_batch, CFG.train_seq
        toks = jnp.asarray(rng.integers(0, CFG.target.vocab, (B, S)), jnp.int32)
        (old_logp,) = M.logprobs_fwd(CFG.target, ws, toks)
        mask = np.zeros((B, S), np.float32)
        mask[:, S // 2:] = 1.0  # response half
        return ws, toks, old_logp, jnp.asarray(mask), rng

    def test_ppo_positive_adv_raises_logp(self):
        """With uniformly positive advantages, the chosen tokens' logprob
        must increase after a few steps."""
        ws, toks, old_logp, mask, rng = self._setup()
        B, S = toks.shape
        adv = jnp.ones((B, S - 1), jnp.float32)
        fn = jax.jit(lambda w, m, v, s, t, ol, a, msk, rl: M.ppo_step(
            CFG.target, w, m, v, s, t, ol, a, msk, rl, 5e-3, 0.2, 0.0, 0.0))
        m, v = _zeros_like_ws(ws), _zeros_like_ws(ws)
        step = jnp.asarray(0.0)
        nw = len(ws)
        cur = ws
        for _ in range(10):
            out = fn(cur, m, v, step, toks, old_logp, adv, mask, old_logp)
            cur = list(out[4 : 4 + nw])
            m = list(out[4 + nw : 4 + 2 * nw])
            v = list(out[4 + 2 * nw : 4 + 3 * nw])
            step = out[4 + 3 * nw]
        (new_logp,) = M.logprobs_fwd(CFG.target, cur, toks)
        msk = np.asarray(mask)[:, 1:]
        gain = ((np.asarray(new_logp) - np.asarray(old_logp)) * msk).sum() / msk.sum()
        assert gain > 0.0, gain

    def test_ppo_zero_adv_zero_pg(self):
        """Zero advantages ⇒ zero policy-gradient loss at step 0."""
        ws, toks, old_logp, mask, _ = self._setup()
        B, S = toks.shape
        adv = jnp.zeros((B, S - 1), jnp.float32)
        loss, (pg, kl, ent) = M._ppo_loss(
            CFG.target, ws, toks, old_logp, adv, mask, 0.2, 0.0, old_logp, 0.0)
        assert abs(float(pg)) < 1e-6
        assert abs(float(kl)) < 1e-5  # ref == current at step 0

    def test_value_step_decreases_mse(self):
        cws = M.init_weights(CFG.critic, jax.random.PRNGKey(5), "value")
        rng = np.random.default_rng(5)
        B, S = CFG.train_batch, CFG.train_seq
        toks = jnp.asarray(rng.integers(0, CFG.critic.vocab, (B, S)), jnp.int32)
        rets = jnp.asarray(rng.standard_normal((B, S)), jnp.float32)
        mask = jnp.ones((B, S), jnp.float32)
        fn = jax.jit(lambda w, m, v, s, t, r, msk: M.value_step(
            CFG.critic, w, m, v, s, t, r, msk, 1e-2))
        losses, _ = _run_steps(fn, cws, 25, toks, rets, mask)
        assert losses[-1] < losses[0], losses

    def test_reward_bt_separates_pairs(self):
        """Bradley-Terry training drives chosen-reward above rejected."""
        rws = M.init_weights(CFG.reward, jax.random.PRNGKey(6), "reward")
        rng = np.random.default_rng(6)
        B, S = CFG.train_batch, CFG.train_seq
        tok_c = jnp.asarray(rng.integers(0, 20, (B, S)), jnp.int32)
        tok_r = jnp.asarray(rng.integers(30, 60, (B, S)), jnp.int32)
        last = jnp.full((B,), S - 1, jnp.int32)
        fn = jax.jit(lambda w, m, v, s: M.reward_bt_step(
            CFG.reward, w, m, v, s, tok_c, tok_r, last, last, 1e-2))
        m, v = _zeros_like_ws(rws), _zeros_like_ws(rws)
        step = jnp.asarray(0.0)
        nw = len(rws)
        cur = rws
        first = None
        for i in range(25):
            out = fn(cur, m, v, step)
            if first is None:
                first = float(out[0])
            cur = list(out[1 : 1 + nw])
            m = list(out[1 + nw : 1 + 2 * nw])
            v = list(out[1 + 2 * nw : 1 + 3 * nw])
            step = out[1 + 3 * nw]
        (rc,) = M.reward_fwd(CFG.reward, cur, tok_c, last)
        (rr,) = M.reward_fwd(CFG.reward, cur, tok_r, last)
        assert float(out[0]) < first
        assert (np.asarray(rc) > np.asarray(rr)).all()
