"""AOT pipeline sanity: artifacts exist, manifest matches weight specs,
HLO text parses structurally, fingerprint gating works."""

import json
import os

import pytest

from compile.configs import get_config
from compile import model as M
from compile.aot import config_fingerprint

ART = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts"))
TINY = os.path.join(ART, "tiny")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(TINY, "manifest.json")),
    reason="tiny artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(TINY, "manifest.json")) as f:
        return json.load(f)


def test_all_artifact_files_exist(manifest):
    for name, art in manifest["artifacts"].items():
        path = os.path.join(TINY, art["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 1000, name


def test_weight_specs_match_model(manifest):
    cfg = get_config("tiny")
    for mdl, tcfg, head in [("target", cfg.target, "lm"),
                            ("draft", cfg.draft, "lm"),
                            ("critic", cfg.critic, "value"),
                            ("reward", cfg.reward, "reward")]:
        spec = M.weight_spec(tcfg, head)
        man = manifest["weights"][mdl]
        assert len(man) == len(spec)
        for (name, shape), entry in zip(spec, man):
            assert entry["name"] == name
            assert tuple(entry["shape"]) == tuple(shape)


def test_tree_buckets_all_present(manifest):
    cfg = get_config("tiny")
    for mdl in ("target", "draft"):
        for b in cfg.batch_buckets:
            for t in cfg.tree_buckets:
                assert f"{mdl}_tree_b{b}_t{t}" in manifest["artifacts"]


def test_tree_artifact_signature(manifest):
    cfg = get_config("tiny")
    t = cfg.target
    art = manifest["artifacts"]["target_tree_b2_t8"]
    kinds = [a["kind"] for a in art["args"]]
    assert kinds == ["weights", "array", "array", "array", "array", "array",
                     "array"]
    kc = art["args"][1]
    assert kc["shape"] == [t.n_layers, 2, t.n_heads, t.max_seq, t.d_head]
    # outputs: logits [B,T,V], k_new, v_new [L,B,H,T,Dh]
    outs = art["outs"]
    assert outs[0]["shape"] == [2, 8, t.vocab]
    assert outs[1]["shape"] == [t.n_layers, 2, t.n_heads, 8, t.d_head]
    assert outs[2]["shape"] == outs[1]["shape"]


def test_train_step_output_counts(manifest):
    """train steps return loss(+stats) then ws, m, v, step."""
    cfg = get_config("tiny")
    nw = M.n_weights(cfg.target)
    art = manifest["artifacts"]["target_train_lm"]
    assert len(art["outs"]) == 1 + 3 * nw + 1
    ppo = manifest["artifacts"]["target_ppo"]
    assert len(ppo["outs"]) == 4 + 3 * nw + 1


def test_hlo_text_looks_like_hlo(manifest):
    path = os.path.join(TINY, manifest["artifacts"]["target_tree_b1_t1"]["file"])
    with open(path) as f:
        head = f.read(4096)
    assert "HloModule" in head
    assert "ENTRY" in open(path).read()


def test_fingerprint_stable():
    cfg = get_config("tiny")
    assert config_fingerprint(cfg, "pallas") == config_fingerprint(cfg, "pallas")
    assert config_fingerprint(cfg, "pallas") != config_fingerprint(cfg, "ref")


def test_build_info_matches_current_code():
    with open(os.path.join(TINY, "build_info.json")) as f:
        info = json.load(f)
    cfg = get_config("tiny")
    assert info["fingerprint"] == config_fingerprint(cfg, info["attn"]), (
        "artifacts are stale relative to python/compile — re-run `make artifacts`"
    )
