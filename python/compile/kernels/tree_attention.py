"""L1 Pallas kernel: tree-masked flash attention for LLM verification.

This is the paper's compute hot-spot (§5.2: verification cost =
KV-cache-bound attention + draft-token-bound FFN).  The kernel fuses the
two key sources of speculative verification into a single online-softmax
attention pass per (batch, head):

* **prefix phase** — the committed KV cache is streamed HBM→VMEM in
  ``blk_k``-sized tiles along the sequence axis (flash-style running
  max / denominator / accumulator), masked by ``prefix_len``;
* **tree phase** — a final tile over the ``T`` speculative tokens,
  masked by the ancestor matrix ``tree_mask`` so every tree branch
  attends exactly to its own path.

Hardware adaptation (CUDA paper → TPU, see DESIGN.md §3): the paper's
threadblock KV-loading schedule becomes the BlockSpec grid + in-kernel
tile loop; the per-tile VMEM footprint is ``O(T·Dh + blk_k·Dh)``
independent of sequence length; all contractions are [T,Dh]×[Dh,blk_k]
matmuls, which map onto the MXU systolic array.

The kernel MUST run with ``interpret=True`` on this image: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _tree_attn_kernel(
    prefix_ref,  # [1] int32 (this sample's valid cache length)
    q_ref,       # [1, 1, T, Dh]
    kc_ref,      # [1, 1, S, Dh]
    vc_ref,      # [1, 1, S, Dh]
    kt_ref,      # [1, 1, T, Dh]
    vt_ref,      # [1, 1, T, Dh]
    mask_ref,    # [1, T, T] float 0/1 ancestor mask
    o_ref,       # [1, 1, T, Dh]
    *,
    blk_k: int,
):
    T = q_ref.shape[2]
    S = kc_ref.shape[2]
    Dh = q_ref.shape[3]
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, dtype=jnp.float32))

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # [T, Dh]
    prefix_len = prefix_ref[0]

    num_tiles = S // blk_k

    def prefix_tile(i, carry):
        """One HBM→VMEM K/V tile of the committed cache."""
        m_i, l_i, acc = carry
        k = pl.load(kc_ref, (0, 0, pl.dslice(i * blk_k, blk_k), slice(None)))
        v = pl.load(vc_ref, (0, 0, pl.dslice(i * blk_k, blk_k), slice(None)))
        s = jnp.dot(q, k.astype(jnp.float32).T)  # [T, blk_k] — MXU matmul
        pos = i * blk_k + jax.lax.iota(jnp.int32, blk_k)
        s = jnp.where((pos < prefix_len)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jnp.dot(p, v.astype(jnp.float32))
        return m_new, l_new, acc

    m0 = jnp.full((T,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((T,), dtype=jnp.float32)
    acc0 = jnp.zeros((T, Dh), dtype=jnp.float32)
    m_i, l_i, acc = jax.lax.fori_loop(0, num_tiles, prefix_tile, (m0, l0, acc0))

    # Tree phase: the T speculative tokens, gated by the ancestor mask.
    kt = kt_ref[0, 0, :, :].astype(jnp.float32)  # [T, Dh]
    vt = vt_ref[0, 0, :, :].astype(jnp.float32)
    mask = mask_ref[0, :, :]  # [T, T]
    st = jnp.dot(q, kt.T)
    st = jnp.where(mask > 0.5, st, NEG_INF)
    m_new = jnp.maximum(m_i, jnp.max(st, axis=1))
    p = jnp.exp(st - m_new[:, None])
    p = jnp.where(st <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_i - m_new)
    l_new = l_i * alpha + jnp.sum(p, axis=1)
    acc = acc * alpha[:, None] + jnp.dot(p, vt)

    denom = jnp.maximum(l_new, 1e-30)
    o_ref[0, 0, :, :] = (acc / denom[:, None]).astype(o_ref.dtype)


def tree_attention(q, kc, vc, kt, vt, prefix_len, tree_mask, *, blk_k=128,
                   interpret=True):
    """Pallas tree attention; drop-in for ``ref.tree_attention_ref``.

    Shapes as in the reference oracle.  ``S`` (cache capacity) must be a
    multiple of ``blk_k``.  Runs one grid cell per (batch, head); the
    committed cache is consumed in ``blk_k`` tiles with an online softmax.
    """
    B, H, T, Dh = q.shape
    S = kc.shape[2]
    if S % blk_k != 0:
        raise ValueError(f"cache length {S} not a multiple of blk_k {blk_k}")

    kernel = functools.partial(_tree_attn_kernel, blk_k=blk_k)
    grid = (B, H)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),
            pl.BlockSpec((1, 1, T, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, T, T), lambda b, h: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, Dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, Dh), q.dtype),
        interpret=interpret,
    )(prefix_len.astype(jnp.int32), q, kc, vc, kt, vt, tree_mask)


def vmem_bytes(T: int, S: int, Dh: int, blk_k: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set per grid cell (see DESIGN.md §Perf).

    q + one K tile + one V tile + kt + vt + mask + accumulators.
    """
    q = T * Dh
    tile = 2 * blk_k * Dh
    tree = 2 * T * Dh
    mask = T * T
    acc = T * Dh + 2 * T
    return dtype_bytes * (q + tile + tree + mask + acc)
