"""Pure-jnp oracle for the tree-attention verification kernel.

This is the dense, obviously-correct implementation the Pallas kernel is
checked against (pytest + hypothesis sweeps in ``python/tests``).  It is
also the attention used inside *training* step functions, where gradients
must flow (``pallas_call`` has no autodiff rule).

Semantics
---------
Query tokens are the ``T`` speculative-tree tokens of each sample.  Keys
come from two places:

* the committed KV cache ``kc/vc`` (positions ``[0, prefix_len)`` valid),
* the tree tokens themselves, gated by ``tree_mask[b, i, j] == 1``
  (``j`` is an ancestor-or-self of ``i`` in the draft tree).

A single softmax runs over the concatenation, matching autoregressive
attention when the tree degenerates to a causal chain.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q, kc, vc, kt, vt, prefix_len, tree_mask):
    """Dense tree attention.

    Args:
      q:   [B, H, T, Dh] query projections of the tree tokens (RoPE applied).
      kc:  [B, H, S, Dh] committed key cache (RoPE applied at commit time).
      vc:  [B, H, S, Dh] committed value cache.
      kt:  [B, H, T, Dh] keys of the tree tokens (RoPE applied).
      vt:  [B, H, T, Dh] values of the tree tokens.
      prefix_len: [B] int32, number of valid cache positions per sample.
      tree_mask:  [B, T, T] float 0/1, ``[b, i, j] = 1`` iff tree token j is
        visible to tree token i (ancestor-or-self).

    Returns:
      [B, H, T, Dh] attention outputs.
    """
    B, H, T, Dh = q.shape
    S = kc.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, dtype=q.dtype))

    # [B, H, T, S] scores against the cache.
    sc = jnp.einsum("bhtd,bhsd->bhts", q, kc) * scale
    pos = jnp.arange(S, dtype=jnp.int32)[None, None, None, :]
    cache_ok = pos < prefix_len[:, None, None, None].astype(jnp.int32)
    sc = jnp.where(cache_ok, sc, NEG_INF)

    # [B, H, T, T] scores against the tree tokens.
    st = jnp.einsum("bhtd,bhud->bhtu", q, kt) * scale
    st = jnp.where(tree_mask[:, None, :, :] > 0.5, st, NEG_INF)

    s = jnp.concatenate([sc, st], axis=-1)  # [B, H, T, S+T]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    # Zero out fully-masked entries so padding rows stay finite.
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    denom = jnp.maximum(denom, 1e-30)
    v = jnp.concatenate([vc, vt], axis=2)  # [B, H, S+T, Dh]
    return jnp.einsum("bhts,bhsd->bhtd", p / denom, v)
