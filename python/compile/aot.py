"""AOT pipeline: lower every L2 step function to HLO **text** artifacts.

Runs ONCE at build time (``make artifacts``); the rust coordinator then
loads ``artifacts/<config>/*.hlo.txt`` via ``HloModuleProto::from_text_file``
and never touches python again.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Alongside the HLO files we emit ``manifest.json`` describing, for every
artifact, the exact positional argument list (weights are symbolic groups
expanded from the per-model weight spec) and output shapes, so the rust
runtime can marshal literals generically.

Artifact inventory per config (see DESIGN.md §4):

* ``{target,draft}_tree_b{B}_t{T}``  — speculative tree forward (prefill /
  decode / verify).  The KV *cache* is an input only; the new tree-token
  KV rows are returned and committed host-side by rust (saves shipping the
  whole cache back every step).
* ``target_logits``      — distill targets, [B,S] → [B,S,V].
* ``target_logprobs``    — reference/actor per-token log-probs.
* ``critic_value``       — value per position.
* ``reward_score``       — scalar reward per sequence.
* ``target_train_lm``    — LM pretrain step (Adam).
* ``draft_distill``      — KL distillation step (Adam).
* ``target_ppo``         — PPO-clip actor step (Adam).
* ``critic_train``       — value MSE step (Adam).
* ``reward_train``       — Bradley-Terry step (Adam).
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import get_config, SystemConfig, TransformerConfig
from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _ws_specs(cfg: TransformerConfig, head: str):
    return [_spec(s) for _, s in M.weight_spec(cfg, head)]


class Builder:
    """Collects artifacts + manifest entries for one SystemConfig."""

    def __init__(self, sys_cfg: SystemConfig, out_dir: str, attn: str):
        self.cfg = sys_cfg
        self.out = out_dir
        self.attn = attn
        self.manifest = {
            "config": sys_cfg.to_dict(),
            "attn": attn,
            "weights": {},
            "artifacts": {},
        }
        for mdl, tcfg, head in [
            ("target", sys_cfg.target, "lm"),
            ("draft", sys_cfg.draft, "lm"),
            ("critic", sys_cfg.critic, "value"),
            ("reward", sys_cfg.reward, "reward"),
        ]:
            self.manifest["weights"][mdl] = [
                {"name": n, "shape": list(s)} for n, s in M.weight_spec(tcfg, head)
            ]

    def emit(self, name: str, fn, arg_specs, arg_desc):
        """Lower ``fn`` at ``arg_specs`` and record a manifest entry."""
        path = os.path.join(self.out, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        outs = jax.tree_util.tree_leaves(lowered.out_info)
        out_desc = [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs]
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_desc,
            "outs": out_desc,
        }
        print(f"  {name}: {len(text)/1e6:.2f} MB, {len(arg_desc)} arg groups, "
              f"{len(out_desc)} outs")

    # ---- argument-description helpers (symbolic groups keep json small) --

    @staticmethod
    def g_weights(mdl):
        return {"kind": "weights", "model": mdl}

    @staticmethod
    def g_array(name, shape, dtype="float32"):
        return {"kind": "array", "name": name, "shape": list(shape), "dtype": dtype}

    @staticmethod
    def g_scalar(name, dtype="float32"):
        return {"kind": "scalar", "name": name, "dtype": dtype}

    # ----------------------------------------------------------------- tree

    def build_tree(self, mdl: str, tcfg: TransformerConfig):
        L, H, Dh, S = tcfg.n_layers, tcfg.n_heads, tcfg.d_head, tcfg.max_seq
        for B in self.cfg.batch_buckets:
            for T in self.cfg.tree_buckets:
                name = f"{mdl}_tree_b{B}_t{T}"
                fn = functools.partial(
                    M.fwd_tree, tcfg, attn=self.attn, blk_k=self.cfg.blk_k
                )

                def wrapped(ws, kc, vc, tokens, positions, prefix_len, tree_mask,
                            _fn=fn):
                    return _fn(ws, kc, vc, tokens, positions, prefix_len, tree_mask)

                specs = [
                    _ws_specs(tcfg, "lm"),
                    _spec((L, B, H, S, Dh)),
                    _spec((L, B, H, S, Dh)),
                    _spec((B, T), I32),
                    _spec((B, T), I32),
                    _spec((B,), I32),
                    _spec((B, T, T)),
                ]
                desc = [
                    self.g_weights(mdl),
                    self.g_array("kc", (L, B, H, S, Dh)),
                    self.g_array("vc", (L, B, H, S, Dh)),
                    self.g_array("tokens", (B, T), "int32"),
                    self.g_array("positions", (B, T), "int32"),
                    self.g_array("prefix_len", (B,), "int32"),
                    self.g_array("tree_mask", (B, T, T)),
                ]
                self.emit(name, wrapped, specs, desc)

    # ------------------------------------------------------------ forwards

    def build_forwards(self):
        c = self.cfg
        B, S = c.train_batch, c.train_seq

        self.emit(
            "target_logits",
            functools.partial(M.logits_fwd, c.target),
            [_ws_specs(c.target, "lm"), _spec((B, S), I32)],
            [self.g_weights("target"), self.g_array("tokens", (B, S), "int32")],
        )
        self.emit(
            "target_logprobs",
            functools.partial(M.logprobs_fwd, c.target),
            [_ws_specs(c.target, "lm"), _spec((B, S), I32)],
            [self.g_weights("target"), self.g_array("tokens", (B, S), "int32")],
        )
        self.emit(
            "critic_value",
            functools.partial(M.value_fwd, c.critic),
            [_ws_specs(c.critic, "value"), _spec((B, S), I32)],
            [self.g_weights("critic"), self.g_array("tokens", (B, S), "int32")],
        )
        self.emit(
            "reward_score",
            functools.partial(M.reward_fwd, c.reward),
            [_ws_specs(c.reward, "reward"), _spec((B, S), I32), _spec((B,), I32)],
            [
                self.g_weights("reward"),
                self.g_array("tokens", (B, S), "int32"),
                self.g_array("last_pos", (B,), "int32"),
            ],
        )

    # ------------------------------------------------------------ training

    def _train_args(self, mdl, tcfg, head, extra_specs, extra_desc):
        ws = _ws_specs(tcfg, head)
        specs = [ws, ws, ws, _spec(())] + extra_specs
        desc = (
            [
                self.g_weights(mdl),
                {"kind": "adam_m", "model": mdl},
                {"kind": "adam_v", "model": mdl},
                self.g_scalar("step"),
            ]
            + extra_desc
        )
        return specs, desc

    def build_training(self):
        c = self.cfg
        B, S = c.train_batch, c.train_seq
        V = c.target.vocab

        specs, desc = self._train_args(
            "target", c.target, "lm",
            [_spec((B, S), I32), _spec((B, S)), _spec(())],
            [self.g_array("tokens", (B, S), "int32"),
             self.g_array("loss_mask", (B, S)),
             self.g_scalar("lr")],
        )
        self.emit("target_train_lm",
                  functools.partial(M.train_lm_step, c.target), specs, desc)

        specs, desc = self._train_args(
            "draft", c.draft, "lm",
            [_spec((B, S), I32), _spec((B, S, V)), _spec((B, S)), _spec(())],
            [self.g_array("tokens", (B, S), "int32"),
             self.g_array("target_logits", (B, S, V)),
             self.g_array("loss_mask", (B, S)),
             self.g_scalar("lr")],
        )
        self.emit("draft_distill",
                  functools.partial(M.distill_step, c.draft), specs, desc)

        specs, desc = self._train_args(
            "target", c.target, "lm",
            [_spec((B, S), I32), _spec((B, S - 1)), _spec((B, S - 1)),
             _spec((B, S)), _spec((B, S - 1)), _spec(()), _spec(()), _spec(()),
             _spec(())],
            [self.g_array("tokens", (B, S), "int32"),
             self.g_array("old_logp", (B, S - 1)),
             self.g_array("adv", (B, S - 1)),
             self.g_array("mask", (B, S)),
             self.g_array("ref_logp", (B, S - 1)),
             self.g_scalar("lr"), self.g_scalar("clip_eps"),
             self.g_scalar("kl_coef"), self.g_scalar("ent_coef")],
        )
        self.emit("target_ppo",
                  functools.partial(M.ppo_step, c.target), specs, desc)

        specs, desc = self._train_args(
            "critic", c.critic, "value",
            [_spec((B, S), I32), _spec((B, S)), _spec((B, S)), _spec(())],
            [self.g_array("tokens", (B, S), "int32"),
             self.g_array("returns", (B, S)),
             self.g_array("mask", (B, S)),
             self.g_scalar("lr")],
        )
        self.emit("critic_train",
                  functools.partial(M.value_step, c.critic), specs, desc)

        specs, desc = self._train_args(
            "reward", c.reward, "reward",
            [_spec((B, S), I32), _spec((B, S), I32), _spec((B,), I32),
             _spec((B,), I32), _spec(())],
            [self.g_array("tok_chosen", (B, S), "int32"),
             self.g_array("tok_rejected", (B, S), "int32"),
             self.g_array("last_c", (B,), "int32"),
             self.g_array("last_r", (B,), "int32"),
             self.g_scalar("lr")],
        )
        self.emit("reward_train",
                  functools.partial(M.reward_bt_step, c.reward), specs, desc)

    def finish(self):
        path = os.path.join(self.out, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  manifest.json: {len(self.manifest['artifacts'])} artifacts")


def config_fingerprint(cfg: SystemConfig, attn: str) -> str:
    """Hash of everything that determines artifact content (config + code)."""
    h = hashlib.sha256()
    h.update(json.dumps(cfg.to_dict(), sort_keys=True).encode())
    h.update(attn.encode())
    here = os.path.dirname(os.path.abspath(__file__))
    for fname in ["model.py", "aot.py", "configs.py",
                  os.path.join("kernels", "tree_attention.py"),
                  os.path.join("kernels", "ref.py")]:
        with open(os.path.join(here, fname), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def build(config_name: str, out_root: str, attn: str = "pallas",
          force: bool = False, only=None) -> str:
    cfg = get_config(config_name)
    out_dir = os.path.join(out_root, config_name)
    os.makedirs(out_dir, exist_ok=True)
    fp = config_fingerprint(cfg, attn)
    stamp = os.path.join(out_dir, "build_info.json")
    if not force and os.path.exists(stamp):
        with open(stamp) as f:
            if json.load(f).get("fingerprint") == fp:
                print(f"[aot] {config_name}: up to date ({out_dir})")
                return out_dir

    print(f"[aot] building config={config_name} attn={attn} → {out_dir}")
    b = Builder(cfg, out_dir, attn)
    if only is None or "tree" in only:
        b.build_tree("target", cfg.target)
        b.build_tree("draft", cfg.draft)
    if only is None or "fwd" in only:
        b.build_forwards()
    if only is None or "train" in only:
        b.build_training()
    b.finish()
    with open(stamp, "w") as f:
        json.dump({"fingerprint": fp, "config": config_name, "attn": attn}, f)
    return out_dir


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--config", default="tiny,small",
                   help="comma-separated config names (tiny|small|base)")
    p.add_argument("--out", default=None,
                   help="output root (default: <repo>/artifacts)")
    p.add_argument("--attn", default="pallas", choices=["pallas", "ref"],
                   help="attention impl for the generation hot path")
    p.add_argument("--force", action="store_true")
    p.add_argument("--only", default=None,
                   help="subset: comma of tree,fwd,train")
    args = p.parse_args()

    out_root = args.out
    if out_root is None:
        here = os.path.dirname(os.path.abspath(__file__))
        out_root = os.path.normpath(os.path.join(here, "..", "..", "artifacts"))
    only = args.only.split(",") if args.only else None
    for name in args.config.split(","):
        build(name.strip(), out_root, attn=args.attn, force=args.force, only=only)


if __name__ == "__main__":
    main()
