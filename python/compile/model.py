"""L2: the JAX model family for the RLHFSpec reproduction.

Everything here is *pure* (weights in → weights out) so each step function
lowers to a single self-contained HLO module the rust coordinator executes
via PJRT.  Four models (paper §2.1):

* **target / actor**  — generates responses; also the reference model
  (rust keeps a frozen weight copy).
* **draft (SSM)**     — a smaller transformer distilled from the target;
  drives tree-based speculative drafting.
* **critic**          — value model (transformer + scalar head per token).
* **reward**          — scalar-per-sequence head trained with Bradley-Terry.

Weight layout is a *flat list* with deterministic ordering (see
``weight_spec``); the rust side initializes/loads weights positionally
from the manifest emitted by ``aot.py``.

The speculative-verification hot path (``fwd_tree``) calls the Pallas
tree-attention kernel (L1); training paths use the dense jnp oracle since
``pallas_call`` has no autodiff rule.
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .configs import TransformerConfig
from .kernels.ref import tree_attention_ref
from .kernels.tree_attention import tree_attention

# ---------------------------------------------------------------------------
# Weight layout
# ---------------------------------------------------------------------------

# Per-layer weight names, in order.
LAYER_WEIGHTS = ["attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_in", "w_out"]


def weight_spec(cfg: TransformerConfig, head: str = "lm") -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat (name, shape) list defining the positional weight layout.

    ``head`` is one of ``lm`` (logits over vocab), ``value`` (scalar per
    token) or ``reward`` (scalar per token, pooled at the last valid
    position by the caller).
    """
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    spec = [("embedding", (V, D))]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.attn_norm", (D,)),
            (f"l{i}.wq", (D, D)),
            (f"l{i}.wk", (D, D)),
            (f"l{i}.wv", (D, D)),
            (f"l{i}.wo", (D, D)),
            (f"l{i}.ffn_norm", (D,)),
            (f"l{i}.w_in", (D, F)),
            (f"l{i}.w_out", (F, D)),
        ]
    spec.append(("final_norm", (D,)))
    if head == "lm":
        spec.append(("lm_head", (D, V)))
    elif head in ("value", "reward"):
        spec.append(("head", (D, 1)))
    else:
        raise ValueError(head)
    return spec


def n_weights(cfg: TransformerConfig) -> int:
    return 2 + 8 * cfg.n_layers + 1


def init_weights(cfg: TransformerConfig, key, head: str = "lm"):
    """Reference initializer (python-side tests only; rust has its own
    seeded init and the two never need to agree bit-for-bit)."""
    ws = []
    for name, shape in weight_spec(cfg, head):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            ws.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = fan_in ** -0.5
            ws.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return ws


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding.  x: [B, T, H, Dh], positions: [B, T] int32."""
    B, T, H, Dh = x.shape
    half = Dh // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None, None] * freq[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _unpack(cfg: TransformerConfig, ws):
    """Split flat weight list into (embedding, layers, final_norm, head)."""
    emb = ws[0]
    layers = []
    idx = 1
    for _ in range(cfg.n_layers):
        layers.append(ws[idx : idx + 8])
        idx += 8
    final_norm = ws[idx]
    head = ws[idx + 1]
    return emb, layers, final_norm, head


# ---------------------------------------------------------------------------
# Tree forward (prefill / decode / verification) with KV cache
# ---------------------------------------------------------------------------


def fwd_tree(cfg: TransformerConfig, ws, kc, vc, tokens, positions, prefix_len,
             tree_mask, *, attn: str = "pallas", blk_k: int = 128,
             head_mode: str = "lm"):
    """Forward the ``T`` tree tokens against the committed KV cache.

    Args:
      ws: flat weight list per ``weight_spec``.
      kc/vc: [L, B, H, S, Dh] committed KV cache (RoPE already applied to kc).
      tokens: [B, T] int32.
      positions: [B, T] int32 absolute positions (prefix_len + tree depth).
      prefix_len: [B] int32 valid cache length.
      tree_mask: [B, T, T] float 0/1 ancestor-or-self visibility.
      attn: "pallas" (L1 kernel) or "ref" (dense jnp, differentiable).

    Returns:
      logits [B, T, V] (or values [B, T] for value/reward heads),
      k_new [L, B, H, T, Dh], v_new [L, B, H, T, Dh] — the *uncommitted*
      KV rows of the tree tokens (rust commits accepted ones).
    """
    emb, layers, final_norm, head = _unpack(cfg, ws)
    B, T = tokens.shape
    H, Dh = cfg.n_heads, cfg.d_head

    x = jnp.take(emb, tokens, axis=0)  # [B, T, D]
    k_all, v_all = [], []
    for li in range(cfg.n_layers):
        attn_norm, wq, wk, wv, wo, ffn_norm, w_in, w_out = layers[li]
        h = rms_norm(x, attn_norm)
        q = (h @ wq).reshape(B, T, H, Dh)
        k = (h @ wk).reshape(B, T, H, Dh)
        v = (h @ wv).reshape(B, T, H, Dh)
        q = rope(q, positions)
        k = rope(k, positions)
        qh = q.transpose(0, 2, 1, 3)  # [B, H, T, Dh]
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        if attn == "pallas":
            o = tree_attention(qh, kc[li], vc[li], kh, vh, prefix_len,
                               tree_mask, blk_k=blk_k)
        else:
            o = tree_attention_ref(qh, kc[li], vc[li], kh, vh, prefix_len,
                                   tree_mask)
        o = o.transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        x = x + o @ wo
        h2 = rms_norm(x, ffn_norm)
        x = x + (jax.nn.silu(h2 @ w_in)) @ w_out
        k_all.append(kh)
        v_all.append(vh)

    xf = rms_norm(x, final_norm)
    if head_mode == "lm":
        out = xf @ head  # [B, T, V]
    else:
        out = (xf @ head)[..., 0]  # [B, T]
    return out, jnp.stack(k_all), jnp.stack(v_all)


def commit(cfg: TransformerConfig, kc, vc, k_new, v_new, src_idx, dest_pos, valid):
    """Scatter accepted tree-token KV rows into the cache.

    Args:
      kc/vc: [L, B, H, S, Dh] cache.
      k_new/v_new: [L, B, H, T, Dh] tree rows from ``fwd_tree``.
      src_idx:  [B, A] int32 — which tree rows to commit.
      dest_pos: [B, A] int32 — cache positions to write them to.
      valid:    [B, A] float 0/1 — entry a is a real commit.

    Returns updated (kc, vc).
    """
    A = src_idx.shape[1]

    def per_batch(kc_b, vc_b, kn_b, vn_b, src_b, dst_b, val_b):
        # kc_b: [L, H, S, Dh]; kn_b: [L, H, T, Dh]
        for a in range(A):
            s, d, ok = src_b[a], dst_b[a], val_b[a]
            row_k = jax.lax.dynamic_slice_in_dim(kn_b, s, 1, axis=2)  # [L,H,1,Dh]
            row_v = jax.lax.dynamic_slice_in_dim(vn_b, s, 1, axis=2)
            old_k = jax.lax.dynamic_slice_in_dim(kc_b, d, 1, axis=2)
            old_v = jax.lax.dynamic_slice_in_dim(vc_b, d, 1, axis=2)
            new_k = jnp.where(ok > 0.5, row_k, old_k)
            new_v = jnp.where(ok > 0.5, row_v, old_v)
            kc_b = jax.lax.dynamic_update_slice_in_dim(kc_b, new_k, d, axis=2)
            vc_b = jax.lax.dynamic_update_slice_in_dim(vc_b, new_v, d, axis=2)
        return kc_b, vc_b

    # vmap over the batch axis (axis 1 of the cache, axis 0 of indices).
    kc2, vc2 = jax.vmap(per_batch, in_axes=(1, 1, 1, 1, 0, 0, 0), out_axes=(1, 1))(
        kc, vc, k_new, v_new, src_idx, dest_pos, valid
    )
    return kc2, vc2


def fwd_tree_commit(cfg, ws, kc, vc, tokens, positions, prefix_len, tree_mask,
                    src_idx, dest_pos, valid, **kw):
    """Fused prefill: forward a causal chunk AND commit all its KV rows.

    Used for prompt prefill where every token is accepted by construction;
    saves one host round-trip of the tree KV per chunk.
    """
    out, k_new, v_new = fwd_tree(cfg, ws, kc, vc, tokens, positions,
                                 prefix_len, tree_mask, **kw)
    kc2, vc2 = commit(cfg, kc, vc, k_new, v_new, src_idx, dest_pos, valid)
    return out, kc2, vc2


# ---------------------------------------------------------------------------
# Full-sequence forwards (training / inference stage)
# ---------------------------------------------------------------------------


def _causal_logits(cfg, ws, tokens, head_mode="lm"):
    """Dense causal forward without KV cache (differentiable)."""
    B, S = tokens.shape
    H, Dh, L = cfg.n_heads, cfg.d_head, cfg.n_layers
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    mask = jnp.broadcast_to(jnp.tril(jnp.ones((S, S), jnp.float32))[None], (B, S, S))
    # Zero-capacity cache: zeros with prefix_len = 0 (fully masked).
    kc = jnp.zeros((L, B, H, S, Dh), jnp.float32)
    vc = jnp.zeros((L, B, H, S, Dh), jnp.float32)
    prefix = jnp.zeros((B,), jnp.int32)
    out, _, _ = fwd_tree(cfg, ws, kc, vc, tokens, positions, prefix, mask,
                         attn="ref", head_mode=head_mode)
    return out


def logits_fwd(cfg, ws, tokens):
    """[B, S] tokens → [B, S, V] logits (reference-model / distill targets)."""
    return (_causal_logits(cfg, ws, tokens, "lm"),)


def logprobs_fwd(cfg, ws, tokens):
    """Per-token log-prob of the *next* token: returns [B, S-1]."""
    logits = _causal_logits(cfg, ws, tokens, "lm")
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nxt = tokens[:, 1:]
    out = jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]
    return (out,)


def value_fwd(cfg, ws, tokens):
    """Critic values per position: [B, S]."""
    return (_causal_logits(cfg, ws, tokens, "value"),)


def reward_fwd(cfg, ws, tokens, last_pos):
    """Sequence reward: value-head output at the last valid position.

    last_pos: [B] int32 index of the final real token.
    Returns ([B] rewards,).
    """
    vals = _causal_logits(cfg, ws, tokens, "reward")  # [B, S]
    r = jnp.take_along_axis(vals, last_pos[:, None], axis=1)[:, 0]
    return (r,)


# ---------------------------------------------------------------------------
# Optimizer (Adam) and training steps
# ---------------------------------------------------------------------------


def adam_update(ws, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step over flat weight lists."""
    step = step + 1.0
    out_w, out_m, out_v = [], [], []
    for w, g, mi, vi in zip(ws, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * jnp.square(g)
        mhat = mi / (1 - b1 ** step)
        vhat = vi / (1 - b2 ** step)
        out_w.append(w - lr * mhat / (jnp.sqrt(vhat) + eps))
        out_m.append(mi)
        out_v.append(vi)
    return out_w, out_m, out_v, step


def _lm_loss(cfg, ws, tokens, loss_mask):
    logits = _causal_logits(cfg, ws, tokens, "lm")
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nxt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]
    msk = loss_mask[:, 1:]
    return jnp.sum(nll * msk) / jnp.maximum(jnp.sum(msk), 1.0)


def train_lm_step(cfg, ws, m, v, step, tokens, loss_mask, lr):
    """Next-token cross-entropy step (target pretraining).

    Returns (loss, ws'…, m'…, v'…, step').
    """
    loss, grads = jax.value_and_grad(lambda w: _lm_loss(cfg, w, tokens, loss_mask))(ws)
    ws2, m2, v2, step2 = adam_update(ws, grads, m, v, step, lr)
    return (loss, *ws2, *m2, *v2, step2)


def _distill_loss(cfg, ws, tokens, target_logits, loss_mask, temp=1.0):
    logits = _causal_logits(cfg, ws, tokens, "lm")
    logp = jax.nn.log_softmax(logits / temp, axis=-1)
    tgt = jax.nn.softmax(target_logits / temp, axis=-1)
    kl = jnp.sum(tgt * (jnp.log(jnp.maximum(tgt, 1e-9)) - logp), axis=-1)
    return jnp.sum(kl * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)


def distill_step(cfg, ws, m, v, step, tokens, target_logits, loss_mask, lr):
    """KL(target ‖ draft) distillation step for the SSM (paper §5.2: the
    draft-logit ↔ acceptance-probability correlation is *earned* here)."""
    loss, grads = jax.value_and_grad(
        lambda w: _distill_loss(cfg, w, tokens, target_logits, loss_mask))(ws)
    ws2, m2, v2, step2 = adam_update(ws, grads, m, v, step, lr)
    return (loss, *ws2, *m2, *v2, step2)


def _ppo_loss(cfg, ws, tokens, old_logp, adv, mask, clip_eps, kl_coef, ref_logp,
              ent_coef):
    logits = _causal_logits(cfg, ws, tokens, "lm")
    logp_all = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nxt = tokens[:, 1:]
    logp = jnp.take_along_axis(logp_all, nxt[..., None], axis=-1)[..., 0]
    msk = mask[:, 1:]
    denom = jnp.maximum(jnp.sum(msk), 1.0)

    ratio = jnp.exp(logp - old_logp)
    un = ratio * adv
    cl = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    pg = -jnp.sum(jnp.minimum(un, cl) * msk) / denom

    kl = jnp.sum((logp - ref_logp) * msk) / denom
    ent = -jnp.sum(jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1) * msk) / denom
    loss = pg + kl_coef * kl - ent_coef * ent
    return loss, (pg, kl, ent)


def ppo_step(cfg, ws, m, v, step, tokens, old_logp, adv, mask, ref_logp, lr,
             clip_eps, kl_coef, ent_coef):
    """PPO-clip actor update (training stage, paper §2.1).

    old_logp/adv/ref_logp: [B, S-1] aligned to next-token targets;
    mask: [B, S] response mask.
    Returns (loss, pg, kl, entropy, ws'…, m'…, v'…, step').
    """
    (loss, aux), grads = jax.value_and_grad(
        lambda w: _ppo_loss(cfg, w, tokens, old_logp, adv, mask, clip_eps,
                            kl_coef, ref_logp, ent_coef), has_aux=True)(ws)
    pg, kl, ent = aux
    ws2, m2, v2, step2 = adam_update(ws, grads, m, v, step, lr)
    return (loss, pg, kl, ent, *ws2, *m2, *v2, step2)


def _value_loss(cfg, ws, tokens, returns, mask):
    vals = _causal_logits(cfg, ws, tokens, "value")
    err = jnp.square(vals - returns) * mask
    return jnp.sum(err) / jnp.maximum(jnp.sum(mask), 1.0)


def value_step(cfg, ws, m, v, step, tokens, returns, mask, lr):
    """Critic MSE-to-returns update."""
    loss, grads = jax.value_and_grad(
        lambda w: _value_loss(cfg, w, tokens, returns, mask))(ws)
    ws2, m2, v2, step2 = adam_update(ws, grads, m, v, step, lr)
    return (loss, *ws2, *m2, *v2, step2)


def _bt_loss(cfg, ws, tok_c, tok_r, last_c, last_r):
    rc = reward_fwd(cfg, ws, tok_c, last_c)[0]
    rr = reward_fwd(cfg, ws, tok_r, last_r)[0]
    return -jnp.mean(jax.nn.log_sigmoid(rc - rr))


def reward_bt_step(cfg, ws, m, v, step, tok_chosen, tok_rejected, last_c, last_r, lr):
    """Bradley-Terry reward-model update on preference pairs."""
    loss, grads = jax.value_and_grad(
        lambda w: _bt_loss(cfg, w, tok_chosen, tok_rejected, last_c, last_r))(ws)
    ws2, m2, v2, step2 = adam_update(ws, grads, m, v, step, lr)
    return (loss, *ws2, *m2, *v2, step2)
