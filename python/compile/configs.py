"""Model-size configurations for the RLHFSpec reproduction.

Each named config describes the *target* (actor / reference) transformer,
the *draft* (SSM) transformer distilled from it, and the critic / reward
models, plus the static shape buckets the AOT pipeline compiles
executables for.

The paper's testbed uses Llama-3.1-8B + an EAGLE draft head; we substitute
from-scratch transformers (see DESIGN.md §2).  ``tiny`` keeps the pytest
cycle fast, ``small`` is the default real-path config, ``base`` is the
~100M-class config for the headline e2e run.
"""

from dataclasses import dataclass, field, asdict
from typing import List


@dataclass(frozen=True)
class TransformerConfig:
    """Hyper-parameters of one GPT-style transformer."""

    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int  # KV-cache capacity S (static executable shape)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        """Parameter count (embedding + blocks + head, untied)."""
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff
        norms = self.n_layers * 2 * self.d_model + self.d_model
        return (
            2 * self.vocab * self.d_model
            + self.n_layers * per_layer
            + norms
        )


@dataclass(frozen=True)
class SystemConfig:
    """One full AOT build: all four RLHF models + shape buckets."""

    name: str
    target: TransformerConfig
    draft: TransformerConfig
    critic: TransformerConfig
    reward: TransformerConfig
    # Static shape buckets compiled as separate executables.
    batch_buckets: List[int] = field(default_factory=lambda: [1, 2, 4, 8])
    # T buckets for tree/prefill steps (number of tokens fed per call).
    tree_buckets: List[int] = field(default_factory=lambda: [1, 8, 16, 32, 64, 96])
    # A buckets for KV commits (tokens committed per call).
    commit_buckets: List[int] = field(default_factory=lambda: [16, 96])
    # Training-step static shapes.
    train_batch: int = 4
    train_seq: int = 256
    # Pallas kernel K-tile along the cache axis (max_seq must divide).
    blk_k: int = 128

    def to_dict(self):
        d = asdict(self)
        d["target"]["d_head"] = self.target.d_head
        d["draft"]["d_head"] = self.draft.d_head
        d["critic"]["d_head"] = self.critic.d_head
        d["reward"]["d_head"] = self.reward.d_head
        return d


def _tiny() -> SystemConfig:
    t = TransformerConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq=64)
    d = TransformerConfig(vocab=64, d_model=16, n_layers=1, n_heads=2, d_ff=32, max_seq=64)
    return SystemConfig(
        name="tiny",
        target=t,
        draft=d,
        critic=d,
        reward=d,
        batch_buckets=[1, 2],
        tree_buckets=[1, 4, 8, 16],
        commit_buckets=[8, 16],
        train_batch=2,
        train_seq=32,
        blk_k=32,
    )


def _small() -> SystemConfig:
    t = TransformerConfig(vocab=512, d_model=256, n_layers=6, n_heads=8, d_ff=1024, max_seq=384)
    d = TransformerConfig(vocab=512, d_model=128, n_layers=2, n_heads=4, d_ff=512, max_seq=384)
    c = TransformerConfig(vocab=512, d_model=128, n_layers=2, n_heads=4, d_ff=512, max_seq=384)
    return SystemConfig(
        name="small",
        target=t,
        draft=d,
        critic=c,
        reward=c,
        batch_buckets=[1, 2, 4, 8],
        tree_buckets=[1, 8, 16, 32, 64, 96],
        commit_buckets=[16, 96],
        train_batch=4,
        train_seq=256,
        blk_k=128,
    )


def _base() -> SystemConfig:
    """~100M-class target (85.6M blocks + 0.8M embeddings)."""
    t = TransformerConfig(vocab=512, d_model=768, n_layers=12, n_heads=12, d_ff=3072, max_seq=512)
    d = TransformerConfig(vocab=512, d_model=192, n_layers=3, n_heads=6, d_ff=768, max_seq=512)
    c = TransformerConfig(vocab=512, d_model=192, n_layers=3, n_heads=6, d_ff=768, max_seq=512)
    return SystemConfig(
        name="base",
        target=t,
        draft=d,
        critic=c,
        reward=c,
        batch_buckets=[1, 2, 4],
        tree_buckets=[1, 8, 16, 32, 64, 96],
        commit_buckets=[16, 96],
        train_batch=2,
        train_seq=256,
        blk_k=128,
    )


CONFIGS = {
    "tiny": _tiny(),
    "small": _small(),
    "base": _base(),
}


def get_config(name: str) -> SystemConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown config {name!r}; have {sorted(CONFIGS)}")
    return CONFIGS[name]
