#!/usr/bin/env python3
"""CI gate: scheduler overhead must stay within budget.

Parses the BENCH_core.json artifact written by `cargo bench --bench
bench_core` and fails when the control-plane scheduler's per-step wall
time exceeds BUDGET (default 1%) of the *modeled* decode step it
schedules, at batch size 64 (the ROADMAP regression budget). Every
`core/step/<mode>/b<batch>` row is paired with a `.../modeled-step` row
carrying the modeled step duration, so the gate needs no knowledge of
the cost model.

With `--baseline`, also compares each gated row's overhead percentage
against the committed repo-root seed `BENCH_core.json` (the bench
trajectory baseline): a row fails when it regresses by more than
`--regress-factor` (default 3x, generous because the percentage still
carries machine-speed noise in its wall-time numerator) AND its absolute
overhead exceeds a quarter of the hard budget — so tiny-on-tiny noise
never trips the gate, but a real scheduler regression does even while
still under the hard 1% wall.

With `--min-parallel-speedup`, also gates the parallel event engine:
each `core/cluster/<name>/threadsN` row is compared against its
sequential `core/cluster/<name>` row. The gate is *core-aware*: the
bench records the machine it ran on in a `meta/host-cpus` row, and the
floor binds on the widest threadsN row the host could actually run
(largest N with host CPUs >= N), with the floor scaled proportionally
(`floor * N / widest_N`) so a 4-core host enforces a 4-thread floor
instead of report-and-skipping the 8-thread row it cannot measure.
Rows wider than the host are reported only; absent rows are reported
(the bench hasn't been regenerated since the rows were added) rather
than failed, so the floor binds from the first multicore regeneration
onward.

With `--max-trace-overhead`, also gates the trace & metrics plane: the
`core/trace/on` row (full Chrome trace + metrics export on the hetero
event-heap fleet) must stay within the given percentage of the
`core/trace/off` row (the explicitly untraced baseline). Unlike the
speedup gates, absent rows are *malformed* (exit 2): the flag is only
passed by CI legs that just regenerated the bench, so a missing row
means the instrumentation was dropped, not that the bench predates it.

With `--min-admission-speedup`, also gates the sharded admission path:
the `core/admission/p2c` row (power-of-two-choices pick) must beat the
`core/admission/full-scan` row (the O(fleet) least-loaded scan it
replaced) by at least the floor (scan mean_ns / p2c mean_ns >= floor).
Absent rows are reported, not failed, so the gate binds from the first
regeneration that carries them.

With `--max-policy-overhead`, also gates the drafting control plane:
the `core/policy/bandit` row (one contextual-bandit choose + feedback
cycle) must stay within the given percentage of the
`core/policy/modeled-step` row (the decode step each decision
amortizes against); the `core/policy/static` row is reported for
context. Like the trace gate, absent rows are *malformed* (exit 2) —
the flag is only passed by CI legs that just regenerated the bench.

Usage: check_bench_budget.py [BENCH_core.json] [--budget-pct 1.0]
                             [--baseline BENCH_baseline.json]
                             [--regress-factor 3.0]
                             [--min-parallel-speedup 4.0]
                             [--min-admission-speedup 10.0]
                             [--max-trace-overhead 5.0]
                             [--max-policy-overhead 2.0]

Exit codes: 0 = within budget, 1 = over budget/regressed, 2 = malformed
input (missing rows count as malformed — a silently skipped gate is
worse than a failing one).
"""

import argparse
import json
import sys

GATED_BATCH = "b64"


def load_rows(path):
    """Parse a BENCH_*.json file into {name: mean_ns}; None on error."""
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot parse {path}: {e}", file=sys.stderr)
        return None
    by_name = {}
    for row in rows:
        if not isinstance(row, dict) or "name" not in row or "mean_ns" not in row:
            print(f"error: malformed row {row!r} in {path}", file=sys.stderr)
            return None
        by_name[row["name"]] = float(row["mean_ns"])
    return by_name


def overhead_pct(by_name, name):
    """Scheduler overhead %% of the paired modeled step; None if either
    row is absent (e.g. a trimmed baseline) or the pairing is unusable."""
    modeled = by_name.get(f"{name}/modeled-step")
    if name not in by_name or modeled is None or modeled <= 0:
        return None
    return 100.0 * by_name[name] / modeled


def check_parallel_speedup(by_name, floor):
    """Gate `core/cluster/<name>/threadsN` rows against the sequential
    row. Returns a list of failure strings (empty = pass/skip)."""
    host_cpus = by_name.get("meta/host-cpus")
    parallel = {}
    for name in by_name:
        base, sep, tail = name.rpartition("/threads")
        if not sep or not tail.isdigit() or not base.startswith("core/cluster/"):
            continue
        parallel.setdefault(base, []).append(int(tail))
    if not parallel:
        print("parallel-speedup gate: no core/cluster/*/threadsN rows yet "
              "(bench not regenerated since the parallel engine landed) — "
              "skipping")
        return []

    failures = []
    for base, thread_counts in sorted(parallel.items()):
        seq_ns = by_name.get(base)
        if seq_ns is None or seq_ns <= 0:
            failures.append(f"{base} (threadsN rows without a sequential row)")
            continue
        widest_n = max(thread_counts)
        # The floor binds on the widest row the bench host could
        # actually run, scaled to what that width can deliver — a
        # 4-core host enforces `floor * 4 / widest` on threads4 instead
        # of report-and-skipping the threads8 row it cannot measure.
        supported = [n for n in thread_counts
                     if host_cpus is not None and host_cpus >= n]
        gated_n = max(supported) if supported else None
        for n in sorted(thread_counts):
            par_ns = by_name[f"{base}/threads{n}"]
            speedup = seq_ns / par_ns if par_ns > 0 else float("inf")
            eff_floor = floor * n / widest_n
            if host_cpus is None:
                verdict = "unenforced (no meta/host-cpus row in this artifact)"
            elif host_cpus < n:
                verdict = (f"unenforced (bench host had {host_cpus:.0f} CPUs "
                           f"< {n} threads)")
            elif n != gated_n:
                verdict = "reported (floor binds on the widest supported row)"
            elif speedup >= eff_floor:
                verdict = f"OK (floor {eff_floor:.2f}x at {n}/{widest_n} threads)"
            else:
                verdict = f"BELOW FLOOR {eff_floor:.2f}x"
                failures.append(f"{base}/threads{n} "
                                f"({speedup:.2f}x < {eff_floor:.2f}x)")
            print(f"{base}/threads{n}: {seq_ns / 1e6:.1f}ms -> "
                  f"{par_ns / 1e6:.1f}ms = {speedup:.2f}x speedup — {verdict}")
    return failures


def check_admission_speedup(by_name, floor):
    """Gate the power-of-two-choices admission pick against the full
    least-loaded fleet scan it replaced. Returns failure strings."""
    scan_ns = by_name.get("core/admission/full-scan")
    p2c_ns = by_name.get("core/admission/p2c")
    if scan_ns is None or p2c_ns is None:
        print("admission-speedup gate: core/admission/{full-scan,p2c} rows "
              "absent (bench not regenerated since the sharded control "
              "plane landed) — skipping")
        return []
    speedup = scan_ns / p2c_ns if p2c_ns > 0 else float("inf")
    verdict = f"OK (floor {floor}x)" if speedup >= floor \
        else f"BELOW FLOOR {floor}x"
    print(f"core/admission: full-scan {scan_ns / 1e3:.2f}µs vs p2c "
          f"{p2c_ns / 1e3:.3f}µs = {speedup:.1f}x speedup — {verdict}")
    if speedup < floor:
        return [f"core/admission/p2c ({speedup:.2f}x < {floor}x)"]
    return []


def check_trace_overhead(by_name, max_pct):
    """Gate the trace plane: `core/trace/on` must stay within `max_pct`
    percent of `core/trace/off`. Returns (failures, malformed)."""
    off_ns = by_name.get("core/trace/off")
    on_ns = by_name.get("core/trace/on")
    if off_ns is None or on_ns is None or off_ns <= 0:
        print("error: core/trace/{off,on} rows absent or unusable — the "
              "trace-overhead gate was requested but the bench carries no "
              "trace rows", file=sys.stderr)
        return [], True
    pct = 100.0 * (on_ns - off_ns) / off_ns
    verdict = f"OK (ceiling {max_pct}%)" if pct <= max_pct \
        else f"OVER CEILING {max_pct}%"
    print(f"core/trace: off {off_ns / 1e6:.1f}ms vs on {on_ns / 1e6:.1f}ms "
          f"= {pct:+.2f}% overhead — {verdict}")
    if pct > max_pct:
        return [f"core/trace/on ({pct:+.2f}% > {max_pct}%)"], False
    return [], False


def check_policy_overhead(by_name, max_pct):
    """Gate the drafting control plane: the `core/policy/bandit`
    decision (choose + feedback) must stay within `max_pct` percent of
    the `core/policy/modeled-step` row it amortizes against. The static
    row is reported alongside for context. Returns (failures,
    malformed)."""
    step_ns = by_name.get("core/policy/modeled-step")
    bandit_ns = by_name.get("core/policy/bandit")
    static_ns = by_name.get("core/policy/static")
    if step_ns is None or bandit_ns is None or static_ns is None \
            or step_ns <= 0:
        print("error: core/policy/{static,bandit,modeled-step} rows absent "
              "or unusable — the policy-overhead gate was requested but the "
              "bench carries no policy rows", file=sys.stderr)
        return [], True
    pct = 100.0 * bandit_ns / step_ns
    static_pct = 100.0 * static_ns / step_ns
    verdict = f"OK (ceiling {max_pct}%)" if pct <= max_pct \
        else f"OVER CEILING {max_pct}%"
    print(f"core/policy: static {static_ns / 1e3:.2f}µs "
          f"({static_pct:.3f}%), bandit {bandit_ns / 1e3:.2f}µs of a "
          f"{step_ns / 1e6:.1f}ms modeled step = {pct:.3f}% — {verdict}")
    if pct > max_pct:
        return [f"core/policy/bandit ({pct:.3f}% > {max_pct}%)"], False
    return [], False


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="BENCH_core.json")
    ap.add_argument("--budget-pct", type=float, default=1.0,
                    help="max scheduler overhead as %% of a modeled step")
    ap.add_argument("--baseline", default=None,
                    help="committed seed BENCH_core.json to compare against")
    ap.add_argument("--regress-factor", type=float, default=3.0,
                    help="max allowed overhead-%% growth vs the baseline")
    ap.add_argument("--min-parallel-speedup", type=float, default=None,
                    help="fail when the widest host-supported "
                         "core/cluster/*/threadsN row falls below this "
                         "speedup (scaled by N/widest-N) over its "
                         "sequential row, per the meta/host-cpus row")
    ap.add_argument("--min-admission-speedup", type=float, default=None,
                    help="fail when core/admission/p2c is not at least this "
                         "many times faster than core/admission/full-scan")
    ap.add_argument("--max-trace-overhead", type=float, default=None,
                    help="fail when core/trace/on exceeds core/trace/off by "
                         "more than this percentage (absent rows are "
                         "malformed input, exit 2)")
    ap.add_argument("--max-policy-overhead", type=float, default=None,
                    help="fail when the core/policy/bandit decision exceeds "
                         "this percentage of core/policy/modeled-step "
                         "(absent rows are malformed input, exit 2)")
    args = ap.parse_args()

    by_name = load_rows(args.path)
    if by_name is None:
        return 2

    gated = sorted(
        name for name in by_name
        if name.startswith("core/step/")
        and name.endswith(f"/{GATED_BATCH}")
    )
    if not gated:
        print(f"error: no core/step/*/{GATED_BATCH} rows in {args.path} — "
              "the budget gate has nothing to check", file=sys.stderr)
        return 2

    baseline = None
    if args.baseline is not None:
        baseline = load_rows(args.baseline)
        if baseline is None:
            return 2

    failures = []
    for name in gated:
        pct = overhead_pct(by_name, name)
        if pct is None:
            print(f"error: {name} has no usable {name}/modeled-step row",
                  file=sys.stderr)
            return 2
        sched_ns = by_name[name]
        modeled_ns = by_name[f"{name}/modeled-step"]
        status = "OK" if pct <= args.budget_pct else "OVER BUDGET"
        print(f"{name}: scheduler {sched_ns / 1e3:.2f}µs vs modeled step "
              f"{modeled_ns / 1e6:.2f}ms = {pct:.4f}% "
              f"(budget {args.budget_pct}%) {status}")
        if pct > args.budget_pct:
            failures.append(name)
            continue

        if baseline is None:
            continue
        base_pct = overhead_pct(baseline, name)
        if base_pct is None:
            # A brand-new gated row has no trajectory yet: report, don't
            # fail — the next seed refresh will pick it up.
            print(f"  (no baseline row for {name}; trajectory starts here)")
            continue
        ratio = pct / base_pct if base_pct > 0 else float("inf")
        regressed = (ratio > args.regress_factor
                     and pct > args.budget_pct / 4.0)
        trend = "REGRESSED" if regressed else "ok"
        print(f"  vs committed baseline: {base_pct:.4f}% -> {pct:.4f}% "
              f"({ratio:.2f}x, allowed {args.regress_factor}x) {trend}")
        if regressed:
            failures.append(f"{name} (baseline regression)")

    if args.min_parallel_speedup is not None:
        failures.extend(
            check_parallel_speedup(by_name, args.min_parallel_speedup))

    if args.min_admission_speedup is not None:
        failures.extend(
            check_admission_speedup(by_name, args.min_admission_speedup))

    if args.max_trace_overhead is not None:
        trace_failures, malformed = check_trace_overhead(
            by_name, args.max_trace_overhead)
        if malformed:
            return 2
        failures.extend(trace_failures)

    if args.max_policy_overhead is not None:
        policy_failures, malformed = check_policy_overhead(
            by_name, args.max_policy_overhead)
        if malformed:
            return 2
        failures.extend(policy_failures)

    if failures:
        print(f"FAIL: {len(failures)} row(s) over the "
              f"{args.budget_pct}% scheduler-overhead budget "
              f"or regressed vs the committed baseline: "
              f"{', '.join(failures)}", file=sys.stderr)
        return 1
    against = " and the committed baseline" if baseline is not None else ""
    print(f"PASS: all {len(gated)} gated rows within the "
          f"{args.budget_pct}% budget{against}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
