#!/usr/bin/env python3
"""CI gate: scheduler overhead must stay within budget.

Parses the BENCH_core.json artifact written by `cargo bench --bench
bench_core` and fails when the control-plane scheduler's per-step wall
time exceeds BUDGET (default 1%) of the *modeled* decode step it
schedules, at batch size 64 (the ROADMAP regression budget). Every
`core/step/<mode>/b<batch>` row is paired with a `.../modeled-step` row
carrying the modeled step duration, so the gate needs no knowledge of
the cost model.

Usage: check_bench_budget.py [BENCH_core.json] [--budget-pct 1.0]

Exit codes: 0 = within budget, 1 = over budget, 2 = malformed input
(missing rows count as malformed — a silently skipped gate is worse
than a failing one).
"""

import argparse
import json
import sys

GATED_BATCH = "b64"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="BENCH_core.json")
    ap.add_argument("--budget-pct", type=float, default=1.0,
                    help="max scheduler overhead as %% of a modeled step")
    args = ap.parse_args()

    try:
        with open(args.path) as f:
            rows = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot parse {args.path}: {e}", file=sys.stderr)
        return 2

    by_name = {}
    for row in rows:
        if not isinstance(row, dict) or "name" not in row or "mean_ns" not in row:
            print(f"error: malformed row {row!r}", file=sys.stderr)
            return 2
        by_name[row["name"]] = float(row["mean_ns"])

    gated = sorted(
        name for name in by_name
        if name.startswith("core/step/")
        and name.endswith(f"/{GATED_BATCH}")
    )
    if not gated:
        print(f"error: no core/step/*/{GATED_BATCH} rows in {args.path} — "
              "the budget gate has nothing to check", file=sys.stderr)
        return 2

    failures = []
    for name in gated:
        modeled_name = f"{name}/modeled-step"
        if modeled_name not in by_name:
            print(f"error: {name} has no paired {modeled_name} row",
                  file=sys.stderr)
            return 2
        sched_ns = by_name[name]
        modeled_ns = by_name[modeled_name]
        if modeled_ns <= 0:
            print(f"error: non-positive modeled step for {name}",
                  file=sys.stderr)
            return 2
        pct = 100.0 * sched_ns / modeled_ns
        status = "OK" if pct <= args.budget_pct else "OVER BUDGET"
        print(f"{name}: scheduler {sched_ns / 1e3:.2f}µs vs modeled step "
              f"{modeled_ns / 1e6:.2f}ms = {pct:.4f}% "
              f"(budget {args.budget_pct}%) {status}")
        if pct > args.budget_pct:
            failures.append(name)

    if failures:
        print(f"FAIL: {len(failures)} row(s) over the "
              f"{args.budget_pct}% scheduler-overhead budget: "
              f"{', '.join(failures)}", file=sys.stderr)
        return 1
    print(f"PASS: all {len(gated)} gated rows within the "
          f"{args.budget_pct}% budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
