#!/usr/bin/env python3
"""Analyze a recorded trace: stage breakdown, stragglers, idle gaps.

Consumes the two files the `[trace]` plane writes (see
`rust/src/sim/trace.rs` and docs/ARCHITECTURE.md § Observability):

* the Chrome trace-event JSON (`trace.json`) — per-sample lifecycle
  spans, migration legs, crash/recover instants, engine beat counters —
  the same file Perfetto loads;
* the metrics JSON next to it (`trace_metrics.json`) — counters,
  log-linear histograms and the per-instance stage-seconds breakdown.

Three reports:

1. **Stage breakdown** (the paper's §7.7 view): fleet-total seconds per
   pipeline stage (prefill / draft / select / verify / accept / commit /
   migration) with percentages — where the virtual time actually went.
2. **Top-k stragglers**: the longest `decode` spans with their sample
   id, instance and queueing delay — the samples that held the batch.
3. **Idle gaps**: per-instance gaps between consecutive `round` spans
   longer than `--idle-gap` seconds (weight barriers, crash downtime,
   drained queues), plus each instance's busy fraction of the makespan.

Usage: trace_summary.py trace.json [--metrics trace_metrics.json]
                                   [--top 5] [--idle-gap 0.25]

The metrics path defaults to the trace path's `_metrics.json` sibling
(the same rule the recorder uses). Exit codes: 0 = ok, 2 = unreadable
or malformed input.
"""

import argparse
import json
import sys


def load_json(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot parse {path}: {e}", file=sys.stderr)
        return None


def derive_metrics_path(trace_path):
    """Mirror TraceConfig::derive_metrics_path in rust/src/sim/trace.rs."""
    if trace_path.endswith(".json"):
        return trace_path[: -len(".json")] + "_metrics.json"
    return trace_path + ".metrics.json"


def stage_breakdown(metrics):
    """Fleet-total seconds per pipeline stage from the per-instance
    breakdown the recorder exports at finish()."""
    instances = metrics.get("instances", [])
    totals = {}
    for inst in instances:
        for stage, secs in inst.get("stages", {}).items():
            totals[stage] = totals.get(stage, 0.0) + float(secs)
    return totals, len(instances)


def print_stage_table(totals, n_instances):
    print(f"== Stage breakdown ({n_instances} instances) ==")
    grand = sum(totals.values())
    if grand <= 0:
        print("  (no stage time recorded)")
        return
    width = max(len(s) for s in totals)
    for stage, secs in sorted(totals.items(), key=lambda kv: -kv[1]):
        pct = 100.0 * secs / grand
        bar = "#" * int(round(pct / 2))
        print(f"  {stage:<{width}}  {secs:10.3f}s  {pct:5.1f}%  {bar}")
    print(f"  {'total':<{width}}  {grand:10.3f}s")


def spans(events, name=None):
    """All complete spans (ph == X), optionally filtered by name, as
    (start_s, dur_s, tid, args) tuples in seconds."""
    out = []
    for e in events:
        if e.get("ph") != "X":
            continue
        if name is not None and e.get("name") != name:
            continue
        out.append((e.get("ts", 0.0) / 1e6, e.get("dur", 0.0) / 1e6,
                    e.get("tid", 0), e.get("args", {})))
    return out


def print_stragglers(events, top):
    decode = spans(events, "decode")
    queued = {}
    for start, dur, _tid, args in spans(events, "queued"):
        sid = args.get("sample")
        if sid is not None:
            queued[sid] = dur
    print(f"== Top {top} straggler samples (longest decode spans) ==")
    if not decode:
        print("  (no decode spans in trace)")
        return
    decode.sort(key=lambda s: -s[1])
    for start, dur, tid, args in decode[:top]:
        sid = args.get("sample", "?")
        inst = tid - 3  # Track::Instance(i) <-> tid i+3
        q = queued.get(sid, 0.0)
        extra = f", queued {q:.3f}s" if q > 0 else ""
        print(f"  sample {sid}: {dur:.3f}s decode on instance {inst} "
              f"(tokens {args.get('tokens', '?')}, "
              f"rounds {args.get('rounds', '?')}{extra})")


def print_idle_gaps(events, threshold):
    rounds = {}
    for start, dur, tid, _args in spans(events, "round"):
        if tid >= 3:
            rounds.setdefault(tid - 3, []).append((start, start + dur))
    print(f"== Idle gaps > {threshold}s between decode rounds ==")
    if not rounds:
        print("  (no round spans in trace)")
        return
    makespan = max(end for spanlist in rounds.values() for _s, end in spanlist)
    total_gaps = 0
    for inst in sorted(rounds):
        spanlist = sorted(rounds[inst])
        busy = sum(end - start for start, end in spanlist)
        gaps = []
        prev_end = spanlist[0][0]
        for start, end in spanlist:
            if start - prev_end > threshold:
                gaps.append((prev_end, start - prev_end))
            prev_end = max(prev_end, end)
        total_gaps += len(gaps)
        frac = 100.0 * busy / makespan if makespan > 0 else 0.0
        worst = f", worst {max(g for _t, g in gaps):.3f}s at " \
                f"t={max(gaps, key=lambda g: g[1])[0]:.3f}s" if gaps else ""
        print(f"  instance {inst}: busy {frac:5.1f}% of makespan, "
              f"{len(gaps)} gap(s){worst}")
    print(f"  total: {total_gaps} gap(s) across {len(rounds)} instances, "
          f"makespan {makespan:.3f}s")


def print_counters(metrics):
    counters = metrics.get("counters", {})
    if not counters:
        return
    print("== Selected counters ==")
    keys = ["cluster/arrivals", "cluster/admissions", "cluster/completions",
            "cluster/rounds", "migration/orders", "migration/retransmits",
            "crash/crashes", "crash/samples_requeued", "realloc/decisions",
            "federation/orders", "loop/train_steps", "engine/beats",
            "engine/fallbacks"]
    for k in keys:
        if k in counters:
            print(f"  {k}: {counters[k]}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON from [trace]")
    ap.add_argument("--metrics", default=None,
                    help="metrics JSON (default: derived from the trace "
                         "path, x.json -> x_metrics.json)")
    ap.add_argument("--top", type=int, default=5,
                    help="straggler samples to list")
    ap.add_argument("--idle-gap", type=float, default=0.25,
                    help="minimum idle gap (virtual seconds) to report")
    args = ap.parse_args()

    doc = load_json(args.trace)
    if doc is None:
        return 2
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        print(f"error: {args.trace} carries no traceEvents array",
              file=sys.stderr)
        return 2

    metrics_path = args.metrics or derive_metrics_path(args.trace)
    metrics = load_json(metrics_path)
    if metrics is None:
        return 2

    totals, n_instances = stage_breakdown(metrics)
    print_stage_table(totals, n_instances)
    print()
    print_stragglers(events, args.top)
    print()
    print_idle_gaps(events, args.idle_gap)
    print()
    print_counters(metrics)
    return 0


if __name__ == "__main__":
    sys.exit(main())
